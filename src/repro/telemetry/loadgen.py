"""Synthetic trace generators for the replay harness.

Emits the SAME schema ``telemetry.tracer`` records from a real serving
run (``serve.py --trace-out``), so recorded and generated traces are
interchangeable replay inputs. A generated record is an *arrival*: its
``t`` is when the op arrives (inter-arrival process chosen by the
workload), ``wall_s`` is 0.0 (no timing was observed — the replay
measures it), and the v2 fields carry the workload name, seed and, for
tenant-skewed traffic, the per-tick active-tenant subset.

Workloads (``WORKLOADS``):

steady    Poisson arrivals at a constant ``rate`` — the paper's
          single-stream regime, the baseline every other workload is
          compared against.
bursty    on/off modulated Poisson: within each ``burst_period``
          seconds the first ``burst_duty`` fraction arrives at
          ``rate * burst_factor``, the rest at a trickle. The tail-
          latency stressor: queue depth spikes at burst onsets.
diurnal   rate ramps linearly 0 -> peak -> 0 over the trace (a
          compressed day): tests behavior across a full load sweep in
          one replay.
zipf      steady arrivals, but each tick activates a Zipf(a)-weighted
          random tenant subset — heavy tenant skew, the multi-tenant
          fairness stressor. Records carry the ``active`` list so
          replay reproduces the exact masks.

Every workload interleaves a read op (``predict`` for classification,
``intervals`` for regression) every ``predict_every`` observes. All
randomness comes from one ``numpy`` Generator seeded by ``seed`` —
byte-identical traces across runs.

Passing a ``robustness.faults.FaultPlan`` via ``faults=`` stamps its
traffic/timing schedule onto the records (tracer schema v3): a value
fault at step s becomes ``rec["fault"] = {"kind", "tenant"}`` on the
s-th observe record, a ``duplicate_arrival`` additionally picks the
earlier observe it re-delivers (``of_seq``, keyed), and a ``delay``
sets ``rec["delay_s"]``. The base trace is UNCHANGED by the plan
(same rng consumption), so a faulted trace differs from its fault-free
oracle only in the stamped fields.

    from repro.telemetry import loadgen, write_trace
    recs = loadgen.generate("bursty", ops=512, tenants=8, capacity=128)
    write_trace("bursty.jsonl", recs)
"""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.telemetry.tracer import (SCHEMA_VERSION, capacity_bucket,
                                    validate_record)

WORKLOADS = ("steady", "bursty", "diurnal", "zipf")


def _rate_at(workload: str, t: float, horizon: float, *, rate: float,
             burst_period: float, burst_duty: float,
             burst_factor: float) -> float:
    """Instantaneous arrival rate of the workload at time ``t``."""
    if workload == "steady" or workload == "zipf":
        return rate
    if workload == "bursty":
        phase = (t % burst_period) / burst_period
        if phase < burst_duty:
            return rate * burst_factor
        # off phase: a trickle, never exactly zero (arrivals must make
        # progress through the off window)
        return max(rate / burst_factor, 1e-3)
    if workload == "diurnal":
        # triangle ramp 0 -> 1 -> 0 across the horizon, floored so the
        # trace tails don't stall
        frac = 0.0 if horizon <= 0 else min(max(t / horizon, 0.0), 1.0)
        ramp = 1.0 - abs(2.0 * frac - 1.0)
        return rate * max(ramp, 0.05)
    raise ValueError(f"unknown workload {workload!r} (known: {WORKLOADS})")


def _zipf_weights(tenants: int, a: float) -> np.ndarray:
    w = 1.0 / np.arange(1, tenants + 1, dtype=np.float64) ** a
    return w / w.sum()


def generate(workload: str, *, ops: int, tenants: int, capacity: int,
             engine: str = "classification", rate: float = 2000.0,
             seed: int = 0, predict_every: int = 16,
             burst_period: float = 0.25, burst_duty: float = 0.2,
             burst_factor: float = 8.0, zipf_a: float = 1.2,
             zipf_active_frac: float = 0.5,
             slo_s: float | None = None,
             faults=None) -> list[dict[str, Any]]:
    """Build ``ops`` schema-valid trace records for one workload.

    ``rate`` is the mean arrival rate (ops/s) of the *trace clock*;
    replay rescales it via ``--speedup``. ``predict_every > 0``
    interleaves one read op (predict/intervals) every that many
    observes; 0 disables reads. ``zipf_active_frac`` sets the expected
    fraction of tenants active per zipf tick (sampled without
    replacement by Zipf weight — low-rank tenants appear rarely).
    ``faults`` (a ``robustness.faults.FaultPlan``) stamps its traffic/
    timing schedule onto the records — see the module docstring.
    Returns the records (write with ``tracer.write_trace``).
    """
    if workload not in WORKLOADS:
        raise ValueError(f"unknown workload {workload!r} "
                         f"(known: {WORKLOADS})")
    if ops < 1:
        raise ValueError("ops must be >= 1")
    if tenants < 1:
        raise ValueError("tenants must be >= 1")
    read_op = "intervals" if engine == "regression" else "predict"
    rng = np.random.default_rng(seed)
    horizon = ops / rate  # mean-rate horizon, used by the diurnal ramp
    weights = _zipf_weights(tenants, zipf_a) if workload == "zipf" else None
    n_active = (max(1, int(round(zipf_active_frac * tenants)))
                if workload == "zipf" else tenants)

    records: list[dict[str, Any]] = []
    t = 0.0
    since_read = 0
    for seq in range(ops):
        r = _rate_at(workload, t, horizon, rate=rate,
                     burst_period=burst_period, burst_duty=burst_duty,
                     burst_factor=burst_factor)
        t += float(rng.exponential(1.0 / r))
        rec: dict[str, Any] = {
            "schema": SCHEMA_VERSION,
            "seq": seq,
            "t": t,
            "op": "observe",
            "wall_s": 0.0,
            "tenants": tenants,
            "ticks": 1,
            "capacity": int(capacity),
            "cap_bucket": capacity_bucket(capacity),
            "engine": engine,
            "workload": workload,
            "seed": seed,
        }
        if slo_s is not None:
            rec["slo_s"] = float(slo_s)
        if predict_every and since_read >= predict_every:
            rec["op"] = read_op
            del rec["ticks"]
            since_read = 0
        else:
            since_read += 1
            if weights is not None:
                act = rng.choice(tenants, size=n_active, replace=False,
                                 p=weights)
                rec["active"] = sorted(int(s) for s in act)
        if faults is not None and rec["op"] == "observe":
            _stamp_faults(rec, faults, seq, tenants,
                          [r["seq"] for r in records
                           if r["op"] == "observe"])
        validate_record(rec)
        records.append(rec)
    return records


def _stamp_faults(rec: dict[str, Any], faults, seq: int, tenants: int,
                  observe_seqs: list) -> None:
    """Stamp a FaultPlan's schedule for step ``seq`` onto one observe
    record (schema v3 ``fault`` / ``delay_s`` fields). Duck-typed on
    ``faults.at(site, step)`` / ``faults.seed`` so this module stays
    free of a robustness import."""
    for f in faults.at("traffic", seq):
        if f.kind == "delay":
            rec["delay_s"] = rec.get("delay_s", 0.0) + float(f.param)
        elif f.kind == "duplicate_arrival":
            if not observe_seqs:
                continue  # nothing earlier to re-deliver
            pick = np.random.default_rng(
                (int(faults.seed), 0xD0B, seq))
            rec["fault"] = {
                "kind": f.kind,
                "tenant": int(f.tenant) % tenants,
                "of_seq": int(observe_seqs[
                    int(pick.integers(len(observe_seqs)))]),
            }
        else:
            rec["fault"] = {"kind": f.kind,
                            "tenant": int(f.tenant) % tenants}


__all__ = ["WORKLOADS", "generate"]
