"""Device-side tick counters carried alongside engine state.

The serving engines advance every tenant inside one donated jitted
``lax.scan`` — host code never sees which lanes evicted, wrapped their
ring, or how full they are, and syncing the state out to look would
destroy the O(cap) in-place path. The key observation is that every
tick statistic is a *closed form* of the pre-chunk integer bookkeeping
leaves (``n``/``head``/``wrap``) and the chunk's (T, S) active mask:
occupancy evolves as ``min(n0 + cumsum(active), window)``, an eviction
fires exactly on active ticks that start window-full, and the ring
head advances once per eviction — so ring wraps per session are
``(head0 + evictions) // wrap``. The whole (len(STAT_KEYS),) int32
stat vector is therefore computed *outside the scan body* in one
fused O(T·S) integer pass per chunk (zero added work inside the
per-tick loop, where even a few extra ops measure as a several-%
regression), and the engine folds each chunk's vector into a tiny
device-resident accumulator (one async jitted add per chunk — no host
sync on the hot path). ``TickStats.drain()`` converts the accumulator
to host ints and publishes metrics; only exporters pay the sync.

Bit-exactness: the stats are pure reads of integer leaves that never
feed the float arithmetic, so the instrumented step's p-values and
state are bit-identical to the uninstrumented step's
(property-tested in tests/test_telemetry.py). Donation is unaffected:
the reads happen before the donated buffers are overwritten, and the
(cap, cap) float leaves are never touched.

Per-tick stats (each reduced over the session axis):

    ticks          active lanes this tick
    evictions      active lanes at a full window (the decremental path
                   runs; 0 by construction in grow mode)
    ring_wraps     evictions whose head pointer rolls over to slot 0
    backfills      exact-backfill reductions run (== evictions on both
                   engines: every ring eviction repairs the k-NN lists
                   with one fused reduction)
    occupancy_max  max post-tick live count over sessions
    occupancy_sum  sum of post-tick live counts (mean = sum / sessions)
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

# stats whose accumulation over ticks is a max, not a sum
_MAX_KEYS = ("occupancy_max",)
STAT_KEYS = ("ticks", "evictions", "ring_wraps", "backfills",
             "occupancy_sum", "occupancy_max")
_MAX_MASK_IDX = tuple(STAT_KEYS.index(k) for k in _MAX_KEYS)


def make_chunk_stats_fn(n_of: Callable, head_of: Callable,
                        wrap_of: Callable):
    """Build the in-graph chunk-level stats function for one engine.

    ``n_of``/``head_of``/``wrap_of`` read the per-session occupancy,
    ring head, and ring modulus arrays from the *stacked* engine state
    (e.g. ``lambda s: s.knn.n`` / ``lambda s: s.n``). The returned
    ``stats_fn(state, windows, actives)`` evaluates on the pre-chunk
    state and the chunk's (T, S) active mask and returns a
    (len(STAT_KEYS),) int32 vector in ``STAT_KEYS`` order — the exact
    per-tick counts, computed in closed form (module doc) rather than
    inside the scan body.
    """

    def stats_fn(state, windows, actives) -> jnp.ndarray:
        i32 = jnp.int32
        n0 = n_of(state)
        head0 = head_of(state)
        wrap = wrap_of(state)
        w = windows
        act = actives.astype(i32)                       # (T, S)
        c = jnp.cumsum(act, axis=0)                     # arrivals <= t
        n_after = jnp.minimum(n0[None, :] + c, w[None, :])
        n_pre = jnp.minimum(n0[None, :] + c - act, w[None, :])
        # active tick at a full window => the decremental evict runs
        # (grow mode passes w = cap + 1, so n_pre < w always: zero)
        ev = (actives & (n_pre >= w[None, :])).astype(i32)
        ev_total = jnp.sum(ev, axis=0)                  # (S,)
        # one head step per eviction, mod wrap: full turns completed
        wraps = (head0 + ev_total) // wrap - head0 // wrap
        return jnp.stack([
            jnp.sum(act),        # ticks
            jnp.sum(ev),         # evictions
            jnp.sum(wraps),      # ring_wraps
            jnp.sum(ev),         # backfills (== evictions)
            jnp.sum(n_after),    # occupancy_sum
            jnp.max(n_after),    # occupancy_max
        ])

    return stats_fn


def combine(acc: jnp.ndarray, stat: jnp.ndarray) -> jnp.ndarray:
    """Accumulate one stat vector into another (sum, max where marked)."""
    is_max = jnp.zeros((len(STAT_KEYS),), bool)
    is_max = is_max.at[jnp.asarray(_MAX_MASK_IDX)].set(True)
    return jnp.where(is_max, jnp.maximum(acc, stat), acc + stat)


_fold_into = jax.jit(combine)


class TickStats:
    """Host-side accumulator for the engines' per-chunk stat vectors.

    ``fold(vec)`` merges one chunk's accumulated (len(STAT_KEYS),)
    vector into the running device accumulator — ONE async jitted
    dispatch, no host sync (a dozen eager ops here would be measurable
    host overhead on the per-tick path). ``drain()`` syncs the
    accumulator to host ints, publishes them to ``metrics`` under
    ``engine_<stat>`` (counters for the monotone ones, gauges for the
    occupancy watermarks), and resets it.
    """

    def __init__(self, metrics=None, *, engine: str = "classification"):
        self.metrics = metrics
        self.engine = engine
        self._acc: Any | None = None
        self.totals: dict[str, int] = {k: 0 for k in STAT_KEYS}
        # last drain's per-shard rows (sharded engines only): one
        # {stat: int} dict per shard, in mesh order
        self.shard_vals: list[dict[str, int]] = []

    def fold(self, vec: jnp.ndarray) -> None:
        if self._acc is None:
            self._acc = vec
        else:
            self._acc = _fold_into(self._acc, vec)

    def reset(self) -> None:
        """Discard the pending accumulator and totals without
        publishing (e.g. to exclude warmup dispatches from a run)."""
        self._acc = None
        self.totals = {k: 0 for k in STAT_KEYS}
        self.shard_vals = []

    def drain(self) -> dict[str, int]:
        """Sync + publish + reset; returns this drain's host values."""
        if self._acc is None:
            return {k: 0 for k in STAT_KEYS}
        import numpy as np

        host = np.asarray(self._acc)
        if host.ndim == 2:
            # sharded chunk: one row per shard (mesh order). Merge rows
            # the same way ticks merge — sum, max for the watermarks —
            # and keep the per-shard rows for occupancy reporting.
            self.shard_vals = [
                {k: int(row[i]) for i, k in enumerate(STAT_KEYS)}
                for row in host]
            merged = host.sum(axis=0)
            for i in _MAX_MASK_IDX:
                merged[i] = host[:, i].max()
            host = merged
        vals = {k: int(host[i]) for i, k in enumerate(STAT_KEYS)}
        self._acc = None
        for k, v in vals.items():
            if k in _MAX_KEYS:
                self.totals[k] = max(self.totals[k], v)
            else:
                self.totals[k] += v
        if self.metrics is not None:
            for k, v in vals.items():
                if k in _MAX_KEYS:
                    # high-water mark over the whole run
                    self.metrics.gauge(
                        f"engine_{k}", engine=self.engine).set(
                        self.totals[k])
                else:
                    # mean occupancy = occupancy_sum_total / ticks_total
                    self.metrics.counter(
                        f"engine_{k}_total", engine=self.engine).inc(v)
        return vals


__all__ = ["STAT_KEYS", "combine", "make_chunk_stats_fn", "TickStats"]
