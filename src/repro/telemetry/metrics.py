"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Deliberately tiny (no deps, no threads, no exposition server): serving
code increments in-process objects; ``to_text()`` renders a
prometheus-style plain-text snapshot and ``dump()`` writes the same
snapshot as JSON. Histograms use *fixed* bucket boundaries chosen at
construction, so ``observe`` is an O(log B) bisect and quantile
estimates (p50/p99) come from linear interpolation inside the bucket —
the standard fixed-bucket estimator, exact whenever a quantile lands on
a bucket boundary.

Metric identity is ``(name, sorted label items)``; the same name may
carry different label sets (e.g. ``ops_total{op="observe_many"}``).

    reg = MetricsRegistry()
    reg.counter("engine_ticks_total", op="observe_many").inc(64)
    reg.histogram("observe_many_wall_s").observe(0.0123)
    print(reg.to_text())
    reg.dump("metrics.json")

A process-wide default registry (``get_registry()``) backs callers that
don't thread an explicit one; tests swap it with ``set_registry`` or
pass fresh instances.
"""
from __future__ import annotations

import bisect
import json
import math
import threading
import time
from typing import Any, Iterable

# Default latency buckets (seconds): 1us .. ~100s, quarter-decade steps.
# Wide enough for a compile-included first dispatch and fine enough to
# resolve sub-ms steady-state ticks.
DEFAULT_LATENCY_BUCKETS = tuple(
    10.0 ** (e / 4.0) for e in range(-24, 9))  # 1e-6 .. 1e2


def _label_key(labels: dict[str, Any]) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape_label_value(v: str) -> str:
    """Prometheus exposition-format label-value escaping: backslash,
    double quote and newline must be escaped (in that order — escaping
    the backslash first keeps the other escapes unambiguous)."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Monotone counter. ``inc`` accepts any non-negative increment."""

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} decremented by {v}")
        self.value += float(v)

    def merge(self, other: "Counter") -> None:
        """Fold another shard's counter in: counts sum."""
        self.value += other.value


class Gauge:
    """Last-write-wins instantaneous value."""

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = math.nan

    def set(self, v: float) -> None:
        self.value = float(v)

    def merge(self, other: "Gauge", policy: str = "max") -> None:
        """Fold another shard's gauge in. ``policy``: "max" (default —
        watermarks), "min", "sum" (additive occupancy), or "last"
        (other wins). An unset side (NaN) never clobbers a set one."""
        if math.isnan(other.value):
            return
        if math.isnan(self.value) or policy == "last":
            self.value = other.value
        elif policy == "max":
            self.value = max(self.value, other.value)
        elif policy == "min":
            self.value = min(self.value, other.value)
        elif policy == "sum":
            self.value += other.value
        else:
            raise ValueError(f"unknown gauge merge policy {policy!r}")


class Histogram:
    """Fixed-bucket histogram with count/sum and quantile estimation.

    ``bounds`` are the inclusive upper edges of the finite buckets; one
    implicit overflow bucket catches everything above the last edge.
    ``quantile(q)`` interpolates linearly within the bucket containing
    the q-th observation (overflow observations report the last finite
    edge — a lower bound, flagged by ``quantile_is_lower_bound``).
    """

    def __init__(self, name: str, labels: tuple,
                 bounds: Iterable[float] = DEFAULT_LATENCY_BUCKETS):
        self.name = name
        self.labels = labels
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly increasing")
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket edge")
        self.counts = [0] * (len(self.bounds) + 1)  # +1: overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def merge(self, other: "Histogram") -> None:
        """Fold another shard's histogram in (bucket-wise adds).

        Both histograms must share bucket boundaries — the merged
        counts are then exactly the histogram of the union stream, so
        quantile estimates degrade no further than either input's.
        """
        if other.bounds != self.bounds:
            raise ValueError(
                f"histogram {self.name}: cannot merge mismatched bucket "
                f"boundaries ({len(self.bounds)} vs {len(other.bounds)} "
                "edges)")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def quantile(self, q: float) -> float:
        """Fixed-bucket quantile estimate of the q-th observation."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        rank = q * self.count  # observations at or below the answer
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                if i >= len(self.bounds):  # overflow bucket
                    return max(self.bounds[-1], self.min)
                lo = self.bounds[i - 1] if i > 0 else min(self.min, 0.0)
                hi = self.bounds[i]
                frac = (rank - cum) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                # the true observations bound the bucket estimate
                return min(max(est, self.min), self.max)
            cum += c
        return self.max  # q == 1 with trailing empties

    def quantile_is_lower_bound(self, q: float) -> bool:
        """True when ``quantile(q)`` fell in the overflow bucket."""
        if self.count == 0:
            return False
        rank = q * self.count
        return self.count - self.counts[-1] < rank and self.counts[-1] > 0

    def snapshot(self) -> dict[str, Any]:
        # ``empty`` makes the zero-observation edge explicit: every
        # quantile/min/max below is NaN by definition, not by accident,
        # and downstream consumers can branch on the flag instead of
        # NaN-sniffing.
        return {
            "count": self.count,
            "sum": self.sum,
            "empty": self.count == 0,
            "min": self.min if self.count else math.nan,
            "max": self.max if self.count else math.nan,
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Holds every metric of one process (or one test)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, Counter | Gauge | Histogram] = {}
        self.created_at = time.time()

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (cls.__name__, name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, _label_key(labels), **kw)
                self._metrics[key] = m
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, bounds=DEFAULT_LATENCY_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def merge(self, other: "MetricsRegistry", *,
              gauge_policy: str = "max") -> "MetricsRegistry":
        """Fold another registry's metrics into this one (returns self).

        Counters sum, histograms add bucket-wise (matching boundaries
        required), gauges merge under ``gauge_policy`` ("max" default,
        or "min"/"sum"/"last"). Metric identity is (type, name,
        labels) — disjoint series are adopted wholesale, shared series
        merged value-wise. Identity: merging an empty registry is a
        no-op. Commutative up to gauge policy: with "max"/"min"/"sum",
        a.merge(b) and b.merge(a) agree on every counter, gauge, and
        histogram value (tested). This is the sharded-replay collection
        path: one registry per shard, merged into the report registry.
        """
        with other._lock:
            theirs = list(other._metrics.items())
        for key, m in theirs:
            cls = type(m)
            kw = {"bounds": m.bounds} if isinstance(m, Histogram) else {}
            mine = self._get(cls, m.name, dict(m.labels), **kw)
            if isinstance(m, Gauge):
                mine.merge(m, policy=gauge_policy)
            else:
                mine.merge(m)
        return self

    # -- export -------------------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        """JSON-serializable snapshot: one entry per metric."""
        out: list[dict[str, Any]] = []
        with self._lock:
            items = list(self._metrics.values())
        for m in sorted(items, key=lambda m: (m.name, m.labels)):
            entry: dict[str, Any] = {
                "name": m.name,
                "labels": dict(m.labels),
                "type": type(m).__name__.lower(),
            }
            if isinstance(m, Histogram):
                entry.update(m.snapshot())
            else:
                entry["value"] = m.value
            out.append(entry)
        return {"exported_at": time.time(), "metrics": out}

    def to_text(self) -> str:
        """Prometheus-flavored plain-text snapshot (one line per series;
        histograms render count/sum/p50/p99). The single human-readable
        formatting code path for every serving mode."""
        lines = []
        with self._lock:
            items = list(self._metrics.values())
        for m in sorted(items, key=lambda m: (m.name, m.labels)):
            ls = _label_str(m.labels)
            if isinstance(m, Histogram):
                s = m.snapshot()
                lines.append(
                    f"{m.name}{ls} count={s['count']} sum={s['sum']:.6g} "
                    f"p50={s['p50']:.6g} p99={s['p99']:.6g} "
                    f"max={s['max']:.6g}"
                    + (" empty=1" if s["empty"] else ""))
                # proper exposition series: rates (rate(name_count)) and
                # averages (name_sum / name_count) stay computable by
                # standard prometheus tooling, which cannot parse the
                # human-readable summary line above
                lines.append(f"{m.name}_count{ls} {s['count']}")
                lines.append(f"{m.name}_sum{ls} {s['sum']:.9g}")
            else:
                v = m.value
                vs = f"{v:.6g}" if isinstance(v, float) else str(v)
                lines.append(f"{m.name}{ls} {vs}")
        return "\n".join(lines)

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)


_global_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry."""
    return _global_registry


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide registry (returns the previous one)."""
    global _global_registry
    prev = _global_registry
    _global_registry = reg
    return prev


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_LATENCY_BUCKETS", "get_registry", "set_registry"]
