"""Per-engine instrumentation bundle used by the serving engines.

``EngineTelemetry`` owns everything an instrumented engine needs:

* op timing — each engine-level dispatch (observe / observe_many /
  predict / intervals / pvalues / grow) lands in a latency histogram
  (steady-state calls separate from the compile-including first call
  at each shape signature) and, when a ``Tracer`` is attached, as one
  JSONL trace record with the compile-vs-steady flag.
* device tick stats — the in-graph per-tick counters from
  ``telemetry.device`` folded into a lazy device accumulator
  (``.ticks``); ``drain()`` publishes them.

The timing wrapper never forces a device sync: ``wall_s`` is host wall
time around the (async) dispatch. Loops that synchronize per call
(fetching p-values each tick) therefore get device-true histograms; a
fire-and-forget caller measures enqueue time, which the trace schema
documents. This is what keeps the instrumented hot path inside the
<= 5 % overhead budget that CI enforces.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Callable

from repro.telemetry.device import TickStats, make_chunk_stats_fn
from repro.telemetry.metrics import MetricsRegistry, get_registry
from repro.telemetry.tracer import Tracer


class EngineTelemetry:
    """Instrumentation state attached to one serving engine."""

    def __init__(self, *, engine: str, n_of: Callable | None = None,
                 head_of: Callable | None = None,
                 wrap_of: Callable | None = None,
                 metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None):
        self.engine = engine
        self.metrics = metrics if metrics is not None else get_registry()
        self.tracer = tracer
        # device tick stats need the state accessors; host-only callers
        # (e.g. the registry serving loop) skip them and get timing only
        if n_of is not None:
            self.stats_fn = make_chunk_stats_fn(n_of, head_of, wrap_of)
            self.ticks = TickStats(self.metrics, engine=engine)
        else:
            self.stats_fn = None
            self.ticks = None
        self._seen: set = set()

    def first_call(self, op: str, signature: Any) -> bool:
        key = (op, signature)
        if key in self._seen:
            return False
        self._seen.add(key)
        return True

    def record_op(self, op: str, wall_s: float, *, compile_flag: bool,
                  ticks: int | None = None, tenants: int | None = None,
                  capacity: int | None = None) -> None:
        m = self.metrics
        m.counter("engine_ops_total", op=op, engine=self.engine).inc()
        suffix = "compile_s" if compile_flag else "wall_s"
        m.histogram(f"engine_{op}_{suffix}", engine=self.engine).observe(
            wall_s)
        if self.tracer is not None:
            self.tracer.record(op, wall_s, compile=compile_flag,
                               ticks=ticks, tenants=tenants,
                               capacity=capacity, engine=self.engine)

    @contextlib.contextmanager
    def timed(self, op: str, *, signature: Any = None,
              ticks: int | None = None, tenants: int | None = None,
              capacity: int | None = None):
        """Time one engine dispatch (no forced sync; see module doc)."""
        compile_flag = self.first_call(op, signature)
        ann = contextlib.nullcontext()
        if self.tracer is not None and self.tracer.annotate:
            from jax.profiler import TraceAnnotation
            ann = TraceAnnotation(f"repro.{op}")
        with ann:
            t0 = time.perf_counter()
            yield
            wall = time.perf_counter() - t0
        self.record_op(op, wall, compile_flag=compile_flag, ticks=ticks,
                       tenants=tenants, capacity=capacity)

    def drain(self) -> dict[str, int]:
        """Publish accumulated device tick stats (one host sync)."""
        return self.ticks.drain() if self.ticks is not None else {}


__all__ = ["EngineTelemetry"]
