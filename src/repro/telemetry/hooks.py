"""Per-engine instrumentation bundle used by the serving engines.

``EngineTelemetry`` owns everything an instrumented engine needs:

* op timing — each engine-level dispatch (observe / observe_many /
  predict / intervals / pvalues / grow) lands in a latency histogram
  (steady-state calls separate from the compile-including first call
  at each shape signature) and, when a ``Tracer`` is attached, as one
  JSONL trace record with the compile-vs-steady flag.
* device tick stats — the in-graph per-tick counters from
  ``telemetry.device`` folded into a lazy device accumulator
  (``.ticks``); ``drain()`` publishes them.

The timing wrapper never forces a device sync by default: ``wall_s`` is
host wall time around the (async) dispatch. Loops that synchronize per
call (fetching p-values each tick) therefore get device-true
histograms; a fire-and-forget caller measures enqueue time, which the
trace schema documents. This is what keeps the instrumented hot path
inside the <= 5 % overhead budget that CI enforces.

``sync=True`` opts into device-true timing: the engines hand each
dispatch's output to the yielded handle's ``sync()``, which blocks
until the device finishes *inside* the timed region and stamps the
trace record's ``dispatch_s``. The replay harness uses this — replayed
p50/p99 must measure the device, not the enqueue — while the serving
hot path keeps the default fire-and-forget wrapper.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Callable

from repro.telemetry.device import TickStats, make_chunk_stats_fn
from repro.telemetry.metrics import MetricsRegistry, get_registry
from repro.telemetry.tracer import Tracer


class _TimedHandle:
    """Yielded by ``EngineTelemetry.timed``; carries late record fields.

    ``sync(value)`` is the engines' synchronization hook: a no-op
    pass-through under the default fire-and-forget timing, a
    ``block_until_ready`` (stamping ``dispatch_s``) when the telemetry
    was built with ``sync=True``.
    """

    __slots__ = ("_sync", "_t0", "late")

    def __init__(self, sync_enabled: bool, t0: float):
        self._sync = sync_enabled
        self._t0 = t0
        self.late: dict[str, Any] = {}

    def sync(self, value):
        if self._sync:
            import jax
            jax.block_until_ready(value)
            self.late["dispatch_s"] = time.perf_counter() - self._t0
        return value


class EngineTelemetry:
    """Instrumentation state attached to one serving engine."""

    def __init__(self, *, engine: str, n_of: Callable | None = None,
                 head_of: Callable | None = None,
                 wrap_of: Callable | None = None,
                 metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None, sync: bool = False):
        self.engine = engine
        self.metrics = metrics if metrics is not None else get_registry()
        self.tracer = tracer
        self.sync = sync
        # device tick stats need the state accessors; host-only callers
        # (e.g. the registry serving loop) skip them and get timing only
        if n_of is not None:
            self.stats_fn = make_chunk_stats_fn(n_of, head_of, wrap_of)
            self.ticks = TickStats(self.metrics, engine=engine)
        else:
            self.stats_fn = None
            self.ticks = None
        self._seen: set = set()

    def first_call(self, op: str, signature: Any) -> bool:
        key = (op, signature)
        if key in self._seen:
            return False
        self._seen.add(key)
        return True

    def record_op(self, op: str, wall_s: float, *, compile_flag: bool,
                  ticks: int | None = None, tenants: int | None = None,
                  capacity: int | None = None,
                  dispatch_s: float | None = None) -> None:
        m = self.metrics
        m.counter("engine_ops_total", op=op, engine=self.engine).inc()
        suffix = "compile_s" if compile_flag else "wall_s"
        m.histogram(f"engine_{op}_{suffix}", engine=self.engine).observe(
            wall_s)
        if self.tracer is not None:
            self.tracer.record(op, wall_s, compile=compile_flag,
                               ticks=ticks, tenants=tenants,
                               capacity=capacity, engine=self.engine,
                               dispatch_s=dispatch_s)

    @contextlib.contextmanager
    def timed(self, op: str, *, signature: Any = None,
              ticks: int | None = None, tenants: int | None = None,
              capacity: int | None = None):
        """Time one engine dispatch (no forced sync unless the engine
        routes its output through the yielded handle's ``sync()`` and
        this telemetry was built with ``sync=True``; see module doc)."""
        compile_flag = self.first_call(op, signature)
        ann = contextlib.nullcontext()
        if self.tracer is not None and self.tracer.annotate:
            from jax.profiler import TraceAnnotation
            ann = TraceAnnotation(f"repro.{op}")
        with ann:
            t0 = time.perf_counter()
            handle = _TimedHandle(self.sync, t0)
            yield handle
            wall = time.perf_counter() - t0
        self.record_op(op, wall, compile_flag=compile_flag, ticks=ticks,
                       tenants=tenants, capacity=capacity,
                       dispatch_s=handle.late.get("dispatch_s"))

    def drain(self) -> dict[str, int]:
        """Publish accumulated device tick stats (one host sync)."""
        return self.ticks.drain() if self.ticks is not None else {}


__all__ = ["EngineTelemetry", "_TimedHandle"]
