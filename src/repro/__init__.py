"""repro: exact optimization of conformal predictors (ICML 2021) as a
production JAX framework with multi-pod distribution."""
__version__ = "1.0.0"
