"""Guarded engine tick: input admission + poison-lane quarantine.

A single NaN feature admitted into one tenant's lane contaminates that
lane's maintained (cap, cap) distance matrix and every subsequent
p-value — CP validity is only as good as the stream it conditions on
(Ndiaye's stability analysis formalizes the sensitivity). ``TickGuard``
wraps a serving engine with two defenses, both *outside* the engine's
scan body so the hot per-tick loop is untouched (PR 6's closed-form
tick-counter pattern):

admission (in-graph, per chunk)
    A jitted elementwise check on the observe inputs — features finite,
    label in range (``[0, n_labels)`` classification / finite
    regression), tau in ``[0, 1]`` — folds rejections into the chunk's
    ``active`` mask. A rejected observe simply never happens for that
    lane-tick: state stays bitwise unchanged (the engines' ``active``
    contract) and the returned p-value is NaN. Rejection counts
    accumulate device-side (one async add per chunk) and publish as
    ``guard_rejected_inputs_total{kind}`` on ``drain()``.

poison detection + quarantine (closed form, per sweep)
    In-memory corruption that admission cannot see (bit flips, a buggy
    kernel, a poisoned snapshot) shows up as non-finite values in the
    per-lane float state leaves. The detector is a closed-form
    ``any(~isfinite)`` reduction over the cheap leaves (features +
    neighbour scores — NOT the (S, cap, cap) distance matrix, whose
    poison can only arrive through those same inputs), dispatched
    asynchronously after the chunk and *fetched one sweep later*: the
    (S,) bool synced at sweep point n is the detector output of sweep
    point n-1, whose device work has already drained behind the
    intervening chunk — the hot loop never stalls on the check.
    Non-finite poison is sticky in those leaves, so the one-sweep
    detection lag loses nothing; call ``finalize(state)`` at end of
    stream to flush the last pending check. A tripped lane is FROZEN
    (masked out of every subsequent tick: ``quarantined_lanes`` gauge,
    ``guard_quarantines_total``), then restored from the last committed
    snapshot via the fleet's one-lane repad migration when a
    ``SessionStore`` is attached (``guard_restores_total``); with no
    snapshot available it stays frozen rather than serving garbage.

When the stream is clean the guard is bit-neutral: the effective mask
equals the caller's ``active`` mask, the engine sees identical inputs,
and the dispatch signature never changes — zero new retraces
(property-tested; the chunked-path overhead is CI-gated ≤ 5 %).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

#: rejection-kind order in the device-side accumulator
REJECT_KINDS = ("nonfinite_feature", "label_out_of_range",
                "tau_out_of_range")

class TickGuard:
    """Wrap ``engine`` (classification or regression serving engine)
    with admission + quarantine. Drop-in for the observe path::

        guard = TickGuard(engine, store=session_store, metrics=reg)
        state, p = guard.observe_many(state, xs, ys, taus)

    Reads (``predict`` / ``intervals`` / ``pvalues`` / ``meta`` / ...)
    pass through to the engine untouched.

    Parameters
    ----------
    engine:      a ``ServingEngine`` / ``RegressionServingEngine``.
    store:       optional ``serving.snapshot.SessionStore`` holding
                 committed snapshots of THIS engine's state — the
                 quarantine-restore source. ``None`` => tripped lanes
                 stay frozen.
    metrics:     optional ``MetricsRegistry``.
    check_every: run the poison sweep every N guarded chunks (default
                 2: the deferred (S,) fetch costs one host/device
                 round-trip, and poison is sticky in the checked
                 leaves, so sweeping every other chunk halves the cost
                 at a bounded detection lag; 1 = every chunk).
    """

    def __init__(self, engine, *, store=None, metrics=None,
                 check_every: int = 2):
        self.engine = engine
        self.store = store
        self.metrics = metrics
        self.check_every = max(int(check_every), 1)
        S = engine.n_sessions
        self._classification = hasattr(engine, "n_labels")
        n_labels = getattr(engine, "n_labels", 0)
        classification = self._classification

        def admit(xs, ys, taus, active, qmask, racc):
            ok_x = jnp.all(jnp.isfinite(xs), axis=-1)
            if classification:
                ok_y = (ys >= 0) & (ys < n_labels)
            else:
                ok_y = jnp.isfinite(ys)
            ok_tau = jnp.isfinite(taus) & (taus >= 0.0) & (taus <= 1.0)
            live = active & ~qmask[None, :]
            eff = live & ok_x & ok_y & ok_tau
            counts = jnp.stack([
                jnp.sum(live & ~ok_x),
                jnp.sum(live & ok_x & ~ok_y),
                jnp.sum(live & ok_x & ok_y & ~ok_tau),
            ]).astype(jnp.int32)
            return eff, racc + counts

        def poison_cls(state):
            bad_x = jnp.any(~jnp.isfinite(state.knn.X), axis=(1, 2))
            bad_b = jnp.any(jnp.isnan(state.knn.best), axis=(1, 2))
            return bad_x | bad_b

        def poison_reg(state):
            bad_x = jnp.any(~jnp.isfinite(state.X), axis=(1, 2))
            bad_y = jnp.any(~jnp.isfinite(state.y), axis=1)
            bad_d = jnp.any(jnp.isnan(state.nbr_d), axis=(1, 2))
            return bad_x | bad_y | bad_d

        self._admit = jax.jit(admit)
        self._poison = jax.jit(poison_cls if classification
                               else poison_reg)
        self._qmask = jnp.zeros((S,), dtype=bool)
        self.quarantined: set = set()
        self._racc = jnp.zeros((len(REJECT_KINDS),), dtype=jnp.int32)
        self._chunks = 0
        self._ones = None  # cached all-ones active mask, keyed by shape
        self._pending = None  # deferred (S,) poison flags, device-side
        self._quarantines = 0
        self._restores = 0
        self._cache_step = None
        self._cache_state = None

    # -- observe path -------------------------------------------------------

    def observe(self, state, x, y, tau, active=None):
        """Guarded T=1 tick; same contract as ``engine.observe``."""
        state, p = self.observe_many(state, x[None], y[None], tau[None],
                                     None if active is None
                                     else active[None])
        return state, p[0]

    def observe_many(self, state, xs, ys, taus, active=None):
        """Guarded chunk: admission-filtered ``engine.observe_many``
        followed by the poison sweep (every ``check_every`` chunks)."""
        eng = self.engine
        xs = jnp.asarray(xs)
        ys = jnp.asarray(ys)
        taus = jnp.asarray(taus)
        ydt = jnp.int32 if self._classification else eng.dtype
        if active is None:  # cached all-ones mask: no per-chunk alloc
            if self._ones is None or self._ones.shape != ys.shape:
                self._ones = jnp.ones(ys.shape, dtype=bool)
            active = self._ones
        eff, self._racc = self._admit(
            xs, ys if ys.dtype == ydt else ys.astype(ydt),
            taus, jnp.asarray(active), self._qmask, self._racc)
        state, p = eng.observe_many(state, xs, ys, taus, active=eff)
        self._chunks += 1
        if self._chunks % self.check_every == 0:
            state = self._sweep(state)  # consumes the PREVIOUS flags
            self._pending = self._poison(state)  # async; fetched next
        return state, p

    def finalize(self, state):
        """Flush the deferred poison check at end of stream (the last
        chunk's flags are still pending). Returns the possibly lane-
        restored state; call before ``drain()``."""
        state = self._sweep(state)
        self._pending = self._poison(state)
        return self._sweep(state)

    # -- quarantine ---------------------------------------------------------

    def _sweep(self, state):
        """Consume the pending poison flags; freeze newly tripped lanes,
        then try a restore. The flags were computed on an earlier
        version of ``state`` — non-finite poison in the checked leaves
        is sticky, so a lane flagged then is still poisoned now."""
        if self._pending is None:
            return state
        bad = np.asarray(self._pending)
        self._pending = None
        hit = [int(i) for i in np.nonzero(bad)[0]
               if int(i) not in self.quarantined]
        if not hit:
            return state
        for lane in hit:
            self.quarantined.add(lane)
            self._quarantines += 1
            if self.metrics is not None:
                self.metrics.counter("guard_quarantines_total").inc()
        self._sync_qmask()
        for lane in hit:
            state = self._restore_lane(state, lane)
        return state

    def _sync_qmask(self):
        q = np.zeros((self.engine.n_sessions,), dtype=bool)
        for lane in self.quarantined:
            q[lane] = True
        self._qmask = jnp.asarray(q)
        if self.metrics is not None:
            self.metrics.gauge("quarantined_lanes").set(
                len(self.quarantined))

    def _snapshot_state(self):
        """Last committed snapshot state (cached per committed step)."""
        if self.store is None:
            return None
        step = self.store.latest_step()
        if step is None:
            return None
        if step != self._cache_step:
            snap, got, _meta = self.store.restore()  # walk-back enabled
            self._cache_step = step
            self._cache_state = snap
        return self._cache_state

    def _restore_lane(self, state, lane: int):
        """One-lane restore from the snapshot: the fleet's repad
        migration scattered into the live stacked state. On any
        incompatibility (no snapshot, different lane grid, shrinking
        capacity, sliding-window mismatch) the lane just stays frozen."""
        snap = self._snapshot_state()
        if snap is None:
            return state
        eng = self.engine
        S_snap = int(jax.tree_util.tree_leaves(snap)[0].shape[0])
        if S_snap != eng.n_sessions:
            return state
        lane_state = jax.tree_util.tree_map(lambda L: L[lane], snap)
        snap_cap = int(lane_state.D.shape[-1])
        cur_cap = int(state.D.shape[-1])
        if snap_cap != cur_cap:
            if eng._wmax is not None or snap_cap > cur_cap:
                return state
            from repro.serving.fleet import repad_cls, repad_reg
            repad = repad_cls if self._classification else repad_reg
            lane_state = repad(lane_state, cur_cap)
        if any(np.issubdtype(np.asarray(l).dtype, np.floating)
               and not np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree_util.tree_leaves(lane_state)):
            return state  # the snapshot itself is poisoned: stay frozen
        state = jax.tree_util.tree_map(
            lambda L, v: L.at[lane].set(v.astype(L.dtype)), state,
            lane_state)
        state = eng._shard_state(state)
        eng.reset_occupancy()
        self.quarantined.discard(lane)
        self._restores += 1
        if self.metrics is not None:
            self.metrics.counter("guard_restores_total").inc()
        self._sync_qmask()
        return state

    # -- reporting ----------------------------------------------------------

    def drain(self) -> dict:
        """Sync + publish the guard counters; reset the accumulators.

        Returns ``{rejected: {kind: n}, quarantines, restores,
        quarantined_lanes}``."""
        rej = [int(v) for v in np.asarray(self._racc)]
        self._racc = jnp.zeros_like(self._racc)
        if self.metrics is not None:
            for kind, n in zip(REJECT_KINDS, rej):
                if n:
                    self.metrics.counter("guard_rejected_inputs_total",
                                         kind=kind).inc(n)
        out = {
            "rejected": dict(zip(REJECT_KINDS, rej)),
            "quarantines": self._quarantines,
            "restores": self._restores,
            "quarantined_lanes": sorted(self.quarantined),
        }
        self._quarantines = 0
        self._restores = 0
        return out

    def __getattr__(self, name):
        return getattr(self.engine, name)


__all__ = ["TickGuard", "REJECT_KINDS"]
