"""Deterministic keyed fault injection for the serving stack.

The chaos half of the fault-tolerance story: every injected fault is a
pure function of ``(seed, site, step)``, so a failing chaos run replays
bit-identically from its seed — no flaky-fuzz triage. A ``FaultPlan``
is a static schedule of ``Fault``s; a ``FaultInjector`` applies the
plan's I/O and timing faults at named injection points inside
``checkpoint.store.CheckpointStore`` (and through it
``serving.snapshot.SessionStore`` / ``AsyncShardedSaver``); the traffic
kinds are applied to synthetic traffic arrays (``corrupt_traffic``) or
stamped into loadgen trace records (tracer schema v3 ``fault`` /
``delay_s`` fields) and honored by ``telemetry.replay``.

Fault kinds
-----------
I/O (``IO_FAULTS``, applied by ``FaultInjector`` at store sites):
    write_fail     the write attempt raises ``TransientWriteError``
                   (an ``OSError`` — the saver's retry class) for the
                   first ``times`` attempts at that (site, step);
                   ``times < 0`` raises ``PermanentWriteError`` forever
                   (the surfaced-not-retried class).
    partial_write  the written file is truncated to half its size
                   AFTER its checksum was recorded (a torn write the
                   writer itself cannot see — restore detects it).
    corrupt_shard  one byte of the written file is flipped after
                   checksumming (silent disk corruption).
    checksum_flip  the digest recorded in the manifest is perturbed
                   (the file is fine; the metadata lies).
traffic (``TRAFFIC_FAULTS``, applied to observe inputs):
    nan_feature / inf_feature    a feature coordinate becomes NaN/Inf
    label_out_of_range           classification: label >= n_labels;
                                 regression: label becomes Inf
    tau_out_of_range             tie-break tau outside [0, 1]
    duplicate_arrival            the record re-delivers an earlier
                                 event id (at-least-once delivery);
                                 replay's dedup drops it
timing (``TIMING_FAULTS``):
    delay          sleep ``param`` seconds at an I/O site, or delay a
                   trace record's dispatch by ``param`` (``delay_s``)
state (``STATE_FAULTS``, test harness only):
    state_poison   a NaN written straight into one lane's state leaf —
                   the in-memory corruption the admission check cannot
                   see; exercises the guard's poison detector.

Sites (``SITES``): ``store.write`` (entry of a store write attempt),
``store.shard`` (each shard file, post-checksum), ``store.manifest``
(the recorded digest), ``store.commit`` (just before the COMMITTED
marker — the torn-write window), ``traffic`` (per-tick observe
inputs), ``state`` (between chunks, test harness).

This module is deliberately jax-free (numpy + stdlib) so the lint /
CI tooling can import it without a device.
"""
from __future__ import annotations

import os
import time
import zlib
from dataclasses import dataclass

import numpy as np

IO_FAULTS = ("write_fail", "partial_write", "corrupt_shard",
             "checksum_flip")
TRAFFIC_FAULTS = ("nan_feature", "inf_feature", "label_out_of_range",
                  "tau_out_of_range", "duplicate_arrival")
TIMING_FAULTS = ("delay",)
STATE_FAULTS = ("state_poison",)
FAULT_KINDS = IO_FAULTS + TRAFFIC_FAULTS + TIMING_FAULTS + STATE_FAULTS

#: traffic kinds that corrupt observe *values* (the guard's admission
#: check rejects exactly these); duplicate_arrival is a delivery fault
#: handled by replay's dedup instead
VALUE_FAULTS = ("nan_feature", "inf_feature", "label_out_of_range",
                "tau_out_of_range")

SITES = ("store.write", "store.shard", "store.manifest", "store.commit",
         "traffic", "state")


class TransientWriteError(OSError):
    """An injected write failure the saver is expected to retry."""


class PermanentWriteError(RuntimeError):
    """An injected write failure that must be surfaced, never retried."""


def _key_rng(seed: int, site: str, step: int) -> np.random.Generator:
    """The keyed generator: one independent stream per (seed, site,
    step) — the determinism contract of the whole module."""
    return np.random.default_rng(
        (int(seed), zlib.crc32(site.encode("utf-8")), int(step)))


@dataclass(frozen=True)
class Fault:
    """One scheduled fault at ``(site, step)``.

    ``tenant`` scopes traffic/state faults to one lane; ``param`` is
    the delay in seconds (timing) or unused; ``times`` bounds how many
    attempts an I/O fault fires for (``write_fail``: attempts 1..times
    raise, later retries succeed; negative = permanent).
    """

    site: str
    step: int
    kind: str
    tenant: int = -1
    param: float = 0.0
    times: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(known: {FAULT_KINDS})")


class FaultPlan:
    """A static, keyed schedule of faults.

    Either built from an explicit ``faults`` list or drawn by
    ``FaultPlan.random`` — in both cases ``at(site, step)`` is the
    lookup every injection point uses. ``random`` keys each
    (site, step) cell independently via ``(seed, site, step)``, so the
    fault decision at one step never depends on how many steps the
    plan covers (tested).
    """

    def __init__(self, seed: int, faults=()):
        self.seed = int(seed)
        self._by: dict = {}
        for f in faults:
            self._by.setdefault((f.site, f.step), []).append(f)

    def at(self, site: str, step: int) -> tuple:
        return tuple(self._by.get((site, int(step)), ()))

    def faults(self) -> list:
        out = [f for fs in self._by.values() for f in fs]
        return sorted(out, key=lambda f: (f.site, f.step, f.kind))

    def __len__(self) -> int:
        return sum(len(fs) for fs in self._by.values())

    @classmethod
    def random(cls, seed: int, *, steps: int, tenants: int = 1,
               rate: float = 0.02, kinds=VALUE_FAULTS,
               sites=("traffic",), param: float = 0.0,
               times: int = 1) -> "FaultPlan":
        """Draw a keyed random plan: each (site, step) independently
        carries one fault with probability ``rate``, kind and tenant
        drawn from the same keyed stream."""
        kinds = tuple(kinds)
        faults = []
        for site in sites:
            for step in range(int(steps)):
                rng = _key_rng(seed, site, step)
                if rng.random() >= rate:
                    continue
                kind = kinds[int(rng.integers(len(kinds)))]
                tenant = int(rng.integers(max(tenants, 1)))
                faults.append(Fault(site, step, kind, tenant=tenant,
                                    param=param, times=times))
        return cls(seed, faults)


def flip_byte(path: str, *, offset: int | None = None,
              seed: int = 0) -> int:
    """Flip one byte of ``path`` in place (offset keyed by ``seed``
    when not given); returns the offset. The unit-test primitive for
    'plant a flipped byte' and the ``corrupt_shard`` implementation."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot flip a byte of empty file {path}")
    if offset is None:
        offset = int(_key_rng(seed, path and "flip", 0).integers(size))
    with open(path, "r+b") as fh:
        fh.seek(offset)
        b = fh.read(1)
        fh.seek(offset)
        fh.write(bytes([b[0] ^ 0xFF]))
    return offset


class FaultInjector:
    """Applies a plan's I/O + timing faults at named injection sites.

    The store calls ``enter(site, step)`` at the start of an attempt
    (raises ``write_fail``, sleeps ``delay``), ``mutate_file`` after a
    file is written AND checksummed (silent corruption), and
    ``mutate_digest`` on the digest recorded in the manifest
    (``checksum_flip``). Attempt counts per (site, step) make
    transient ``write_fail`` faults clear after ``times`` attempts —
    the saver's retry loop is what survives them.
    """

    def __init__(self, plan: FaultPlan, *, metrics=None):
        self.plan = plan
        self._metrics = metrics
        self._attempts: dict = {}

    def _count(self, fault: Fault) -> None:
        if self._metrics is not None:
            self._metrics.counter("faults_injected_total",
                                  site=fault.site, kind=fault.kind).inc()

    def enter(self, site: str, step: int) -> None:
        key = (site, int(step))
        n = self._attempts.get(key, 0) + 1
        self._attempts[key] = n
        for f in self.plan.at(site, step):
            if f.kind == "delay" and n <= max(f.times, 1):
                self._count(f)
                time.sleep(f.param)
            elif f.kind == "write_fail":
                if f.times < 0:
                    self._count(f)
                    raise PermanentWriteError(
                        f"injected permanent write failure at {site} "
                        f"step {step}")
                if n <= f.times:
                    self._count(f)
                    raise TransientWriteError(
                        f"injected write failure (attempt {n}/{f.times})"
                        f" at {site} step {step}")

    def mutate_file(self, site: str, step: int, path: str) -> None:
        for f in self.plan.at(site, step):
            if f.kind == "partial_write":
                self._count(f)
                size = os.path.getsize(path)
                with open(path, "r+b") as fh:
                    fh.truncate(max(size // 2, 1))
            elif f.kind == "corrupt_shard":
                self._count(f)
                flip_byte(path, seed=self.plan.seed + step)

    def mutate_digest(self, site: str, step: int, digest: str) -> str:
        for f in self.plan.at(site, step):
            if f.kind == "checksum_flip":
                self._count(f)
                rng = _key_rng(self.plan.seed, site, step)
                i = int(rng.integers(len(digest)))
                digest = (digest[:i]
                          + format((int(digest[i], 16) + 1) % 16, "x")
                          + digest[i + 1:])
        return digest


def backoff_schedule(seed: int, step: int, retries: int,
                     base_s: float) -> list:
    """Keyed deterministic exponential backoff: delay_i = base * 2^i *
    (1 + U(0, 0.25)) with U drawn from rng((seed, step, attempt)) —
    the same (seed, step) always waits the same schedule."""
    return [
        float(base_s * (2.0 ** i)
              * (1.0 + np.random.default_rng(
                  (int(seed), int(step), i)).uniform(0.0, 0.25)))
        for i in range(int(retries))]


def poisoned_values(kind: str, *, mode: str, n_labels: int = 2):
    """Replacement (x, y, tau) values for a traffic value fault; a
    ``None`` slot is left unchanged."""
    if kind == "nan_feature":
        return (float("nan"), None, None)
    if kind == "inf_feature":
        return (float("inf"), None, None)
    if kind == "label_out_of_range":
        if mode == "classification":
            return (None, int(n_labels) + 7, None)
        return (None, float("inf"), None)
    if kind == "tau_out_of_range":
        return (None, None, 2.0)
    raise ValueError(f"{kind!r} is not a traffic value fault "
                     f"(known: {VALUE_FAULTS})")


def corrupt_traffic(plan: FaultPlan, xs, ys, taus, *, mode: str,
                    n_labels: int = 2, time_axis: int = 0,
                    site: str = "traffic", t0: int = 0) -> set:
    """Apply the plan's traffic value faults to traffic arrays IN
    PLACE; returns the set of hit ``(step, tenant)`` positions (the
    oracle mask for bit-exactness tests).

    ``xs``/``ys``/``taus`` are numpy arrays with time on ``time_axis``
    and the tenant axis on the other — (T, S, dim)/(T, S) for the
    replay layout, (S, T, dim)/(S, T) with ``time_axis=1`` for the
    launcher's layout.
    """
    T = ys.shape[time_axis]
    S = ys.shape[1 - time_axis]

    def ix(t, s):
        return (t, s) if time_axis == 0 else (s, t)

    hits = set()
    for t in range(T):
        for f in plan.at(site, t0 + t):
            if f.kind not in VALUE_FAULTS:
                continue
            lane = int(f.tenant) % S
            xv, yv, tv = poisoned_values(f.kind, mode=mode,
                                         n_labels=n_labels)
            if xv is not None:
                xs[ix(t, lane) + (0,)] = xv
            if yv is not None:
                ys[ix(t, lane)] = yv
            if tv is not None:
                taus[ix(t, lane)] = tv
            hits.add((t0 + t, lane))
    return hits


def poison_state(state, lane: int, *, value: float = float("nan")):
    """Write ``value`` straight into one lane's feature leaf — the
    in-memory corruption admission cannot catch (exercises the
    guard's poison detector). Returns a new state tree (eager
    ``.at[].set``, no donation)."""
    import dataclasses

    if hasattr(state, "knn"):  # classification Session
        knn = dataclasses.replace(
            state.knn, X=state.knn.X.at[lane, 0, 0].set(value))
        return dataclasses.replace(state, knn=knn)
    return dataclasses.replace(
        state, X=state.X.at[lane, 0, 0].set(value))


__all__ = ["IO_FAULTS", "TRAFFIC_FAULTS", "TIMING_FAULTS", "STATE_FAULTS",
           "VALUE_FAULTS", "FAULT_KINDS", "SITES", "Fault", "FaultPlan",
           "FaultInjector", "TransientWriteError", "PermanentWriteError",
           "flip_byte", "backoff_schedule", "poisoned_values",
           "corrupt_traffic", "poison_state"]
