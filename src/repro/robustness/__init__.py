"""Fault tolerance: keyed fault injection, guarded ticks, quarantine.

``repro.robustness.faults`` is the deterministic chaos harness (every
fault a pure function of ``(seed, site, step)``); ``guard.TickGuard``
is the serving-side defense (input admission + poison-lane quarantine
+ snapshot lane-restore). See each module's docstring for the
contract; tests/test_robustness.py holds the chaos property test.
"""
from repro.robustness.faults import (FAULT_KINDS, IO_FAULTS, SITES,
                                     STATE_FAULTS, TIMING_FAULTS,
                                     TRAFFIC_FAULTS, VALUE_FAULTS, Fault,
                                     FaultInjector, FaultPlan,
                                     PermanentWriteError,
                                     TransientWriteError, backoff_schedule,
                                     corrupt_traffic, flip_byte,
                                     poison_state, poisoned_values)
from repro.robustness.guard import REJECT_KINDS, TickGuard

__all__ = ["Fault", "FaultPlan", "FaultInjector", "TransientWriteError",
           "PermanentWriteError", "TickGuard", "REJECT_KINDS",
           "backoff_schedule", "corrupt_traffic", "flip_byte",
           "poison_state", "poisoned_values", "FAULT_KINDS", "IO_FAULTS",
           "TRAFFIC_FAULTS", "TIMING_FAULTS", "STATE_FAULTS",
           "VALUE_FAULTS", "SITES"]
