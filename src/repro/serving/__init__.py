"""Multi-tenant online conformal-prediction serving.

The paper's incremental&decremental updates make exact full-CP cheap
enough to serve online; this package turns the repo's single-shot CP
primitives into a serving system:

* ``session``  — per-tenant capacity-padded CP state with exact
  decremental eviction (sliding windows) and capacity-doubling growth;
* ``engine``   — micro-batching ``ServingEngine``: one vmapped jitted
  step advances every tenant, Pallas-fused read-only queries;
* ``registry`` — declarative measure registry (k-NN / KDE / LS-SVM and
  user plug-ins) behind one fit/observe/evict/pvalues surface;
* ``snapshot`` — crash-safe tenant-state snapshot/restore, plus the
  async double-buffered sharded saver;
* ``fleet``    — tenant lifecycle (admit/retire/migrate) over
  capacity-bucketed engine pools.
"""
from repro.serving.engine import ServingEngine
from repro.serving.fleet import Fleet
from repro.serving.registry import ConformalPredictor, MeasureSpec
from repro.serving.snapshot import AsyncShardedSaver, SessionStore

__all__ = ["ServingEngine", "ConformalPredictor", "MeasureSpec",
           "SessionStore", "AsyncShardedSaver", "Fleet"]
