"""Per-tenant capacity-padded CP session with exact decremental eviction.

A ``Session`` wraps ``core.online.OnlineKnnState`` (the paper's incremental
simplified-k-NN CP state, Section 9) and adds the one piece the pure
incremental state cannot provide: **exact forgetting**. The paper's
decremental update (Fig. 1 read backwards) removes a training point in
O(n) per affected neighbour list — but a neighbour list that loses its
j-th entry must be backfilled with the (k+1)-th best distance, which the
k-slot state no longer knows. The session therefore maintains the live
pairwise distance matrix ``D`` (built incrementally, one row+column per
``observe`` — the distances are computed once anyway for the p-value), so
eviction backfills from stored exact distances instead of re-deriving
them: bit-exact against fit-from-scratch, no O(n^2 p) recompute.

Storage is a **ring buffer**: a scalar ``head`` names the slot of the
oldest live point and the window occupies slots ``(head + i) % cap``.
Evicting the oldest point is a head advance plus the O(cap·k) list
repair — nothing ever positionally compacts the (cap, cap) ``D`` — so a
full sliding-window tick (evict + observe) is a constant number of
O(cap) in-place writes under donation, matching the paper's App. C.5
per-step bound. The historic linear layout is the ``head == 0`` no-wrap
special case, and ``_sliding_step_compact`` below keeps the old
shift-to-compact implementation alive as the bit-oracle the ring path
is property-tested against.

Invariants (all arrays are capacity-padded, fixed-shape, jit-stable):

* slots ``(head + i) % cap``, ``i in [0, n)`` are live in arrival order;
* ``D[i, j]`` is the Euclidean distance between live slots i and j,
  computed exactly as ``core.online.observe`` computes it
  (``sqrt(max(sum((xi-xj)^2), 0))``); BIG on the diagonal and wherever a
  row/column has never been written. Slots no longer live may hold stale
  values — every reader masks by ring liveness, never by position;
* ``aid`` stamps each slot with a monotone arrival counter at insert
  (the tie-break key of the shared decremental repair,
  ``core.online.drop_backfill``);
* ``knn.best`` rows of live slots always equal what fit-from-scratch on
  the current window would produce (the exactness tests assert this
  bitwise, via the ``to_linear`` normalization).

``observe`` delegates the p-value + learn step to
``core.online.observe_with_dists`` so session p-values are bit-identical
to ``core.online.run_stream``; ``evict_oldest`` is the decremental
update; ``grow`` doubles capacity host-side (retraces only O(log n)
times — the capacity-doubling schedule), normalizing the ring back to
linear order first.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import online
from repro.core.online import (BIG, OnlineKnnState, cshift,
                               next_aid as _next_aid, ring_live,
                               ring_mod as _mod, ring_slots)
from repro.kernels import ops as kops


@jax.tree_util.register_pytree_node_class
@dataclass
class Session:
    """One tenant's sliding-window CP state: k-NN state + live distances."""

    knn: OnlineKnnState  # capacity-padded incremental CP state
    D: jnp.ndarray  # (cap, cap) live pairwise distances, BIG elsewhere
    head: jnp.ndarray  # () slot of the oldest live point (ring start)
    # per-slot arrival ids (monotone at insert). The classification tie
    # rules themselves never consult them (the evicted point is always
    # the earliest arrival, and the backfill value needs only counts and
    # mins) — they are carried for diagnostics, snapshot symmetry with
    # the regression state (whose backfill pick DOES consume them), and
    # plug-in measures that need an explicit arrival order.
    aid: jnp.ndarray  # (cap,)
    wrap: jnp.ndarray  # () ring modulus (<= cap; slots >= wrap inert)

    def tree_flatten(self):
        return ((self.knn, self.D, self.head, self.aid, self.wrap), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.D.shape[-1]


def init(capacity: int, p: int, k: int, dtype=jnp.float32,
         wrap: int | None = None) -> Session:
    """Fresh empty session. ``wrap`` (default: the capacity) is the ring
    modulus — a sliding engine whose window statically bounds occupancy
    confines the ring to the leading ``[:wrap]`` block of every leaf."""
    if capacity < k:
        raise ValueError(
            f"capacity {capacity} < k {k}: the k-best machinery (top_k) "
            "needs at least k rows")
    return Session(
        knn=online.init(capacity, p, k, dtype=dtype),
        D=jnp.full((capacity, capacity), BIG, dtype=dtype),
        head=jnp.zeros((), dtype=jnp.int32),
        aid=jnp.zeros((capacity,), dtype=jnp.int32),
        wrap=jnp.asarray(capacity if wrap is None else wrap, jnp.int32),
    )


def _observe(sess: Session, x_new, y_new, tau, *, k):
    """Smoothed p-value for (x_new, y_new), then learn it — one O(cap) step.

    The p-value is bit-identical to ``core.online.observe`` (it *is* that
    computation); additionally the new point's distance row/column is
    recorded in ``D`` for later exact eviction — two dynamic-update-slices
    that run in place (O(cap) traffic) when the jitted step donates its
    input. The new point lands at ring slot ``(head + n) % wrap``.
    Precondition: n < wrap (callers grow or evict first).
    """
    knn_in = sess.knn
    idx = _mod(sess.head + knn_in.n, sess.wrap)
    knn, p, d = online.observe_with_dists(knn_in, x_new, y_new, tau, k=k,
                                          head=sess.head, wrap=sess.wrap)
    D = sess.D.at[idx, :].set(d).at[:, idx].set(d)
    aid = sess.aid.at[idx].set(
        _next_aid(sess.aid, sess.head, knn_in.n, sess.wrap))
    return Session(knn, D, sess.head, aid, sess.wrap), p


observe = functools.partial(jax.jit, static_argnames=("k",))(_observe)
#: Donating form of ``observe``: the (cap, cap) ``D`` row/column insert
#: updates in place instead of copying the matrix. The input session is
#: DELETED by the call — reusing it afterwards raises ``RuntimeError:
#: Array has been deleted``. Numerics are identical to ``observe``.
observe_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe)


def _evict_oldest(sess: Session, *, k) -> Session:
    """Exact decremental update: forget the oldest live point, O(cap).

    Paper's decremental rule: only points whose same-label k-neighbourhood
    contained the evicted point are affected, and each such list needs
    exactly one repair — drop the evicted entry and backfill the new k-th
    best. The evicted point is the OLDEST, so on distance ties it sorts
    first: if it is in a list at all, it occupies the *first* slot holding
    its distance — an O(k) surgery, no re-sort. The backfill value is
    recovered from the maintained ``D`` by multiset rank (two masked row
    reductions; see ``core.online.drop_backfill``) — same bits as a full
    re-sort, a fraction of the compute.

    Under the ring layout nothing moves: the head slot simply leaves the
    live window (``head`` advances, ``n`` drops) and its stale row,
    column and list are masked out of every later read by ring liveness.
    No (cap, cap) buffer is shifted, copied or even written.
    Precondition: n >= 1 (guarded by callers; under vmap+select the n=0
    lanes compute garbage that the caller's select discards).
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    head = sess.head

    # which survivors held the evicted point in their k-best list?
    # d(i, evicted) <= kth <=> it is among i's k smallest same-label
    # distances (exact on ties: the evicted point is the oldest arrival,
    # so it precedes every equal distance in the list order)
    dcol = sess.D[:, head]
    kth = knn.best[:, -1]
    head2 = _mod(head + 1, sess.wrap)
    n2 = knn.n - 1
    live2 = ring_live(cap, head2, n2, sess.wrap)  # survivors only
    affected = (knn.y == knn.y[head]) & live2 & (dcol <= kth)

    cand = (knn.y[:, None] == knn.y[None, :]) & live2[None, :]
    best2 = online.drop_backfill(knn.best, dcol, cand, sess.D, affected,
                                 k=k)
    return Session(OnlineKnnState(knn.X, knn.y, best2, n2), sess.D,
                   head2, sess.aid, sess.wrap)


evict_oldest = functools.partial(
    jax.jit, static_argnames=("k",))(_evict_oldest)
#: Donating form of ``evict_oldest`` — same numerics, input deleted.
evict_oldest_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_evict_oldest)


def _sliding_step(sess: Session, x_new, y_new, tau, window, active, *, k,
                  evictable: bool = True, wmax: int | None = None):
    """One fused sliding-window tick: evict-if-full, observe, all gated.

    The semantics of ``cond(evict_oldest) -> observe`` with an outer
    ``active`` mask, on the ring layout: eviction is a gated head
    advance plus the shared list repair, the observe core writes the new
    point into the freed ring slot, and every state write is gated
    arithmetically (inactive lanes rewrite their current values, so
    masked state stays bitwise unchanged and the p-value is NaN). The
    (cap, cap) ``D`` is only *read* (one fused reduction pass for the
    backfill) and written at one row + one column — never shifted,
    padded or copied — so with donation the whole tick is a constant
    number of O(cap) in-place writes. Bit-identical to the historic
    compaction form ``_sliding_step_compact`` (property-tested).

    ``evictable=False`` (static) removes the eviction machinery — the
    grow-mode engines never evict, so their tick is a pure donated
    observe. ``wmax`` (static) is the caller's promise that occupancy
    never exceeds it (a sliding engine's window bounds n): the ring then
    lives entirely inside the ``[:wmax]`` block of every leaf (modulus
    ``wmax``), and per-tick cost scales with the *window*, not the
    padded capacity.
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    # static block bound for the leaf slices; the traced modulus is the
    # state's ``wrap`` (engine invariant: wrap <= wmax)
    w = cap if wmax is None or wmax >= cap else wmax
    wrap = sess.wrap
    # slot-space views confined to the ring block (pure reads: static
    # slices fuse into their consumers, nothing is materialized)
    Xw, yw, bw = knn.X[:w], knn.y[:w], knn.best[:w]
    Dw = sess.D[:w, :w]
    aidw = sess.aid[:w]
    head = sess.head
    n = knn.n
    act = jnp.asarray(active)

    if evictable:
        ev = act & (n >= window)
        s = ev.astype(jnp.int32)
        dcol = Dw[:, head]
        head1 = _mod(head + s, wrap)
        n1 = n - s
        live1 = ring_live(w, head1, n1, wrap)
        affected = (ev & (yw == yw[head]) & live1
                    & (dcol <= bw[:, -1]))
        cand = (yw[:, None] == yw[None, :]) & live1[None, :]
        b1 = online.drop_backfill(bw, dcol, cand, Dw, affected, k=k)
    else:
        head1, n1, b1 = head, n, bw

    # price + learn through the same code path as core.online.run_stream
    knn1 = OnlineKnnState(Xw, yw, b1, n1)
    knn2, p, d = online.observe_with_dists(knn1, x_new, y_new, tau, k=k,
                                           head=head1, wrap=wrap)

    # gate on ``active``: the big leaf (D) is written with its own
    # current values on inactive lanes (D is symmetric, so the row at
    # idx equals the column at idx); the small leaves are selects
    idx = _mod(head1 + n1, wrap)
    row = jnp.where(act, d, Dw[idx, :])
    # bit-neutral scheduling marker: list entries are finite and >= 0
    # and so is every value in ``row``, so ``+ b1[0,0] * 0.0`` adds +0.0
    # exactly. It makes the in-place D update *depend* on the backfill
    # reads of D — without the edge, XLA cannot prove the reads happen
    # before the write and protects the donated (cap, cap) buffer with
    # two full copies per tick (the O(cap^2) traffic this layout exists
    # to remove; asserted gone by the HLO test)
    row = row + b1[0, 0] * 0.0
    D2 = sess.D.at[idx, :w].set(row).at[:w, idx].set(row)
    knn3 = OnlineKnnState(
        X=knn.X.at[:w].set(jnp.where(act, knn2.X, Xw)),
        y=knn.y.at[:w].set(jnp.where(act, knn2.y, yw)),
        best=knn.best.at[:w].set(jnp.where(act, knn2.best, b1)),
        n=jnp.where(act, knn2.n, n1),
    )
    new_aid = _next_aid(aidw, head1, n1, wrap)
    aid2 = sess.aid.at[idx].set(jnp.where(act, new_aid, sess.aid[idx]))
    p = jnp.where(act, p, jnp.asarray(jnp.nan, dtype=Xw.dtype))
    return Session(knn3, D2, head1, aid2, wrap), p


def _sliding_step_compact(sess: Session, x_new, y_new, tau, window, active,
                          *, k, evictable: bool = True,
                          wmax: int | None = None):
    """Historic linear-layout sliding tick — the ring path's bit-oracle.

    Keeps arrival order positionally: eviction compacts every leaf down
    one row (and ``D`` one row AND one column) through a padded dynamic
    slice — the O(cap^2)-traffic form the ring layout replaces. Retained
    for the exactness property tests (ring vs compact, leaf for leaf
    after ``to_linear``) and as the benchmark baseline
    (``layout="compact"`` on the engines). Precondition: linear layout
    (``head == 0``), which this step preserves.
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    if wmax is not None and wmax < cap:
        sub = Session(
            OnlineKnnState(knn.X[:wmax], knn.y[:wmax], knn.best[:wmax],
                           knn.n),
            sess.D[:wmax, :wmax], sess.head, sess.aid[:wmax],
            jnp.minimum(sess.wrap, wmax))
        sub2, p = _sliding_step_compact(sub, x_new, y_new, tau, window,
                                        active, k=k, evictable=evictable)
        return Session(
            OnlineKnnState(
                X=knn.X.at[:wmax].set(sub2.knn.X),
                y=knn.y.at[:wmax].set(sub2.knn.y),
                best=knn.best.at[:wmax].set(sub2.knn.best),
                n=sub2.knn.n,
            ),
            D=sess.D.at[:wmax, :wmax].set(sub2.D),
            head=sub2.head,
            aid=sess.aid.at[:wmax].set(sub2.aid),
            wrap=sess.wrap), p
    act = jnp.asarray(active)
    aid = sess.aid
    if evictable:
        ev = act & (knn.n >= window)
        s = ev.astype(jnp.int32)
        live = jnp.arange(cap) < knn.n
        dcol = sess.D[:, 0]
        affected = (ev & (knn.y == knn.y[0]) & live
                    & (dcol <= knn.best[:, -1]))

        # conditional compaction: pad each leaf by one (the pad value IS
        # the compaction fill) and take one dynamic slice at offset
        # s ∈ {0, 1} — identity when s == 0, shift-with-fill when s == 1
        X1 = cshift(knn.X, s, 0)
        y1 = cshift(knn.y, s, -1)
        L1 = cshift(knn.best, s, BIG)
        aid1 = cshift(aid, s, 0)
        Dp = jnp.pad(sess.D, ((0, 1), (0, 1)), constant_values=BIG)
        D1 = jax.lax.dynamic_slice(Dp, (s, s), (cap, cap))
        aff1 = cshift(affected, s, False)
        es1 = cshift(dcol, s, BIG)
        n1 = knn.n - s
        live1 = jnp.arange(cap) < n1
        cand = (y1[:, None] == y1[None, :]) & live1[None, :]
        best1 = online.drop_backfill(L1, es1, cand, D1, aff1, k=k)
    else:
        X1, y1, best1, D1 = knn.X, knn.y, knn.best, sess.D
        aid1, n1 = aid, knn.n

    # price + learn through the same code path as core.online.run_stream
    knn1 = OnlineKnnState(X1, y1, best1, n1)
    knn2, p, d = online.observe_with_dists(knn1, x_new, y_new, tau, k=k)

    # gate on ``active``: the big leaf (D) is written with its own
    # current values on inactive lanes (D is symmetric, so the row at
    # idx equals the column at idx); the small leaves are selects.
    # The clamp keeps an inactive lane at an exactly-full window
    # in bounds (idx == cap otherwise — XLA's pad+slice fusion reads
    # the pad fill there instead of clamping); the write is its own
    # value, so the clamp is bit-neutral wherever the step is defined
    idx = jnp.minimum(n1, cap - 1)
    row = jnp.where(act, d, D1[idx, :])
    D2 = D1.at[idx, :].set(row).at[:, idx].set(row)
    knn3 = OnlineKnnState(
        X=jnp.where(act, knn2.X, X1),
        y=jnp.where(act, knn2.y, y1),
        best=jnp.where(act, knn2.best, best1),
        n=jnp.where(act, knn2.n, n1),
    )
    new_aid = _next_aid(aid1, jnp.zeros((), jnp.int32), n1,
                        jnp.int32(cap))
    aid2 = aid1.at[idx].set(jnp.where(act, new_aid, aid1[idx]))
    p = jnp.where(act, p, jnp.asarray(jnp.nan, dtype=X1.dtype))
    return Session(knn3, D2, sess.head, aid2, sess.wrap), p


def _observe_sliding(sess: Session, x_new, y_new, tau, window, *, k):
    """Evict-if-full then observe: one fixed-shape sliding-window step.

    ``window`` is a traced scalar (per-tenant window sizes never
    retrace). The fused ``_sliding_step`` with every lane active.
    """
    return _sliding_step(sess, x_new, y_new, tau, window, True, k=k)


observe_sliding = functools.partial(
    jax.jit, static_argnames=("k",))(_observe_sliding)
#: Donating form of ``observe_sliding`` — same numerics, input deleted.
observe_sliding_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe_sliding)


@jax.jit
def to_linear(sess: Session) -> Session:
    """Normalize a ring session to the linear layout (head == 0).

    Gathers every leaf into arrival order and resets stale slots to the
    linear inert fills (X=0, y=-1, best=BIG, D=BIG), so the result is
    leaf-for-leaf bit-identical to what a fresh linear session fed the
    same surviving window would hold — the equivalence the exactness
    tests assert. Arrival ids are *renumbered* to their canonical
    positional form 0..n-1 (only their relative order carries meaning;
    absolute counters drift with eviction history). O(cap^2) for the
    ``D`` gather; used by ``grow`` and the tests, never on the serving
    tick.
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    slots = ring_slots(cap, sess.head, sess.wrap)
    live = jnp.arange(cap) < knn.n
    X = jnp.where(live[:, None], knn.X[slots], 0)
    y = jnp.where(live, knn.y[slots], -1)
    best = jnp.where(live[:, None], knn.best[slots], BIG)
    D = jnp.where(live[:, None] & live[None, :],
                  sess.D[slots][:, slots], BIG)
    aid = jnp.where(live, jnp.arange(cap, dtype=jnp.int32), 0)
    return Session(OnlineKnnState(X, y, best, knn.n), D,
                   jnp.zeros((), jnp.int32), aid, jnp.int32(cap))


def grow(sess: Session, factor: int = 2) -> Session:
    """Double (by default) capacity host-side, preserving all live state.

    Shapes change, so jitted steps retrace — but only O(log n) times over
    a session's lifetime, the capacity-doubling schedule. The ring is
    normalized to linear order first (ring positions are modulus-bound,
    so they cannot survive a capacity change). Not jittable.
    """
    cap = sess.capacity
    extra = cap * (factor - 1)
    sess = to_linear(sess)
    knn = sess.knn
    return Session(
        knn=OnlineKnnState(
            X=jnp.pad(knn.X, ((0, extra), (0, 0))),
            y=jnp.pad(knn.y, (0, extra), constant_values=-1),
            best=jnp.pad(knn.best, ((0, extra), (0, 0)),
                         constant_values=BIG),
            n=knn.n,
        ),
        D=jnp.pad(sess.D, ((0, extra), (0, extra)), constant_values=BIG),
        head=sess.head,
        aid=jnp.pad(sess.aid, (0, extra)),
        wrap=jnp.int32(cap * factor),
    )


@functools.partial(jax.jit, static_argnames=("k", "n_labels"))
def predict_pvalues(sess: Session, X_test, *, k, n_labels):
    """Read-only full-CP query: p-values (m, n_labels) for every label.

    Hot path: candidate scores via one masked top-k, then the fused
    score-update + count through ``kernels.ops.cp_knn_counts`` (the
    Pallas kernel on TPU). Non-live slots (ring liveness, not position)
    carry a -BIG sentinel so they are never counted regardless of the
    padded capacity. Every reduction here is over a per-slot multiset —
    counts, sums of top-k-sorted values — so the ring layout produces
    the same bits as the linear layout, stale slots masked.

    Rows whose k-best list is not full (label rarer than k in the
    window) are excluded from the kernel and counted caller-side: the
    kernel's ``sums - kth + d`` update would subtract the BIG padding
    sentinel and swallow the finite part in f32. The caller-side path
    uses the cancellation-safe ``base + (kth or d)`` form of
    ``measures.knn._updated_scores``, so rare labels stay exact.
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    live = ring_live(cap, sess.head, knn.n, sess.wrap)

    d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, knn.X), 0.0))  # (m, cap)
    labels = jnp.arange(n_labels, dtype=knn.y.dtype)
    same = (knn.y[None, :] == labels[:, None]) & live[None, :]  # (l, cap)
    dm = jnp.where(same[None], d[:, None, :], BIG)  # (m, l, cap)
    alpha = jnp.sum(-jax.lax.top_k(-dm, k)[0], axis=-1)  # (m, l)

    kth = knn.best[:, -1]
    full = live & (kth < BIG)  # k-best list fully populated
    sum_same = jnp.where(full, jnp.sum(knn.best, axis=1), -BIG)
    kth_same = jnp.where(full, kth, -BIG)
    counts = kops.cp_knn_counts(
        knn.X, jnp.where(live, knn.y, -1), sum_same, kth_same, X_test,
        alpha, n_labels)

    deficient = live & (kth >= BIG)
    base = jnp.sum(knn.best[:, :-1], axis=1)  # (cap,)
    upd = same[None] & (d[:, None, :] < kth)  # (m, l, cap)
    scores = base + jnp.where(upd, d[:, None, :], kth)
    ge = (scores >= alpha[..., None]) & deficient[None, None, :]
    counts = counts + jnp.sum(ge.astype(counts.dtype), axis=-1)
    return (counts + 1.0) / (knn.n + 1.0)


__all__ = ["Session", "init", "observe", "observe_donated", "evict_oldest",
           "evict_oldest_donated", "observe_sliding",
           "observe_sliding_donated", "grow", "predict_pvalues",
           "to_linear"]
