"""Per-tenant capacity-padded CP session with exact decremental eviction.

A ``Session`` wraps ``core.online.OnlineKnnState`` (the paper's incremental
simplified-k-NN CP state, Section 9) and adds the one piece the pure
incremental state cannot provide: **exact forgetting**. The paper's
decremental update (Fig. 1 read backwards) removes a training point in
O(n) per affected neighbour list — but a neighbour list that loses its
j-th entry must be backfilled with the (k+1)-th best distance, which the
k-slot state no longer knows. The session therefore maintains the live
pairwise distance matrix ``D`` (built incrementally, one row+column per
``observe`` — the distances are computed once anyway for the p-value), so
eviction backfills from stored exact distances instead of re-deriving
them: bit-exact against fit-from-scratch, no O(n^2 p) recompute.

Invariants (all arrays are capacity-padded, fixed-shape, jit-stable):

* rows ``[0, n)`` are live, in arrival order (row 0 is the oldest);
* ``D[i, j]`` is the Euclidean distance between live rows i and j,
  computed exactly as ``core.online.observe`` computes it
  (``sqrt(max(sum((xi-xj)^2), 0))``); BIG on the diagonal, on inert
  rows/columns, and everywhere eviction has compacted past;
* ``knn.best`` rows always equal what fit-from-scratch on the current
  window would produce (the exactness tests assert this bitwise).

``observe`` delegates the p-value + learn step to
``core.online.observe_with_dists`` so session p-values are bit-identical
to ``core.online.run_stream``; ``evict_oldest`` is the decremental
update; ``grow`` doubles capacity host-side (retraces only O(log n)
times — the capacity-doubling schedule).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import online
from repro.core.online import BIG, OnlineKnnState, cshift
from repro.kernels import ops as kops


@jax.tree_util.register_pytree_node_class
@dataclass
class Session:
    """One tenant's sliding-window CP state: k-NN state + live distances."""

    knn: OnlineKnnState  # capacity-padded incremental CP state
    D: jnp.ndarray  # (cap, cap) live pairwise distances, BIG elsewhere

    def tree_flatten(self):
        return ((self.knn, self.D), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.D.shape[-1]


def init(capacity: int, p: int, k: int, dtype=jnp.float32) -> Session:
    if capacity < k:
        raise ValueError(
            f"capacity {capacity} < k {k}: the k-best machinery (top_k) "
            "needs at least k rows")
    return Session(
        knn=online.init(capacity, p, k, dtype=dtype),
        D=jnp.full((capacity, capacity), BIG, dtype=dtype),
    )


def _observe(sess: Session, x_new, y_new, tau, *, k):
    """Smoothed p-value for (x_new, y_new), then learn it — one O(cap) step.

    The p-value is bit-identical to ``core.online.observe`` (it *is* that
    computation); additionally the new point's distance row/column is
    recorded in ``D`` for later exact eviction — two dynamic-update-slices
    that run in place (O(cap) traffic) when the jitted step donates its
    input. Precondition: n < capacity (callers grow or evict first).
    """
    idx = sess.knn.n
    knn, p, d = online.observe_with_dists(sess.knn, x_new, y_new, tau, k=k)
    D = sess.D.at[idx, :].set(d).at[:, idx].set(d)
    return Session(knn, D), p


observe = functools.partial(jax.jit, static_argnames=("k",))(_observe)
#: Donating form of ``observe``: the (cap, cap) ``D`` row/column insert
#: updates in place instead of copying the matrix. The input session is
#: DELETED by the call — reusing it afterwards raises ``RuntimeError:
#: Array has been deleted``. Numerics are identical to ``observe``.
observe_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe)


def _evict_oldest(sess: Session, *, k) -> Session:
    """Exact decremental update: forget the oldest live point.

    Paper's decremental rule: only points whose same-label k-neighbourhood
    contained the evicted point are affected, and each such list needs
    exactly one repair — drop the evicted entry and backfill the new k-th
    best. The evicted point is the OLDEST (lowest arrival index), so on
    distance ties it sorts first: if it is in a list at all, it occupies
    the *first* slot holding its distance — an O(k) surgery, no re-sort.
    The backfill value is recovered from the maintained ``D`` by multiset
    rank: the k-1 surviving list entries hold every remaining candidate
    value below their max t' (plus ``m'`` occurrences of t' itself), so
    the next-best value is t' again if the window holds more than m'
    occurrences of it, else the smallest stored distance above t'. Two
    cheap masked row reductions (a count and a min) replace the old
    top_k over the full (cap, cap) matrix — same bits (every output is a
    stored value), a fraction of the compute. Rows are compacted down by
    one to keep the arrival-order invariant.
    Precondition: n >= 1 (guarded by callers; under vmap+select the n=0
    lanes compute garbage that the caller's select discards).
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    live = jnp.arange(cap) < knn.n

    # which survivors held the evicted point in their k-best list?
    # d(i, evicted) <= kth <=> it is among i's k smallest same-label
    # distances (exact on ties: the evicted point's index is the lowest,
    # so it precedes every equal distance in the list order)
    dcol = sess.D[:, 0]
    kth = knn.best[:, -1]
    affected = (knn.y == knn.y[0]) & live & (dcol <= kth)

    # compact every array down one row (and D one column)
    def shift(a, fill):
        return jnp.concatenate([a[1:], jnp.full_like(a[:1], fill)], axis=0)

    Xs = shift(knn.X, 0)
    ys = shift(knn.y, -1)
    bests = shift(knn.best, BIG)
    Ds = shift(sess.D, BIG)
    Ds = jnp.concatenate(
        [Ds[:, 1:], jnp.full_like(Ds[:, :1], BIG)], axis=1)
    aff = shift(affected, False)
    es = shift(dcol, BIG)  # each survivor's distance to the evicted point

    n2 = knn.n - 1
    live2 = jnp.arange(cap) < n2
    cand = (ys[:, None] == ys[None, :]) & live2[None, :]
    best2 = _drop_backfill(bests, es, cand, Ds, aff, k=k)
    return Session(OnlineKnnState(Xs, ys, best2, n2), Ds)


def _drop_backfill(L, es, cand, Ds, aff, *, k):
    """Repair each row flagged in ``aff``: drop the first list slot
    holding that row's evicted distance ``es`` and backfill the new k-th
    best by multiset rank over the stored distances (``Ds`` masked by the
    ``cand`` candidate mask; see ``core.online.drop_backfill_core``).
    Rows not flagged pass through untouched.
    """
    newL, *_ = online.drop_backfill_core(L, es, cand, Ds, k=k)
    return jnp.where(aff[:, None], newL, L)


evict_oldest = functools.partial(
    jax.jit, static_argnames=("k",))(_evict_oldest)
#: Donating form of ``evict_oldest`` — same numerics, input deleted.
evict_oldest_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_evict_oldest)


def _sliding_step(sess: Session, x_new, y_new, tau, window, active, *, k,
                  evictable: bool = True, wmax: int | None = None):
    """One fused sliding-window tick: evict-if-full, observe, all gated.

    The semantics of ``cond(evict_oldest) -> observe`` with an outer
    ``active`` mask, restructured so the (cap, cap) distance matrix
    moves ONCE per tick instead of three times (evict-branch shift +
    skip-branch passthrough + cond select): the compaction is a single
    per-lane *conditional shift* — a padded dynamic slice at offset
    s ∈ {0, 1} — followed by the shared observe core, whose state writes
    are gated arithmetically (inactive lanes rewrite their current
    values, so masked state stays bitwise unchanged and the p-value is
    NaN). Bit-identical to the unfused form (tested).

    ``evictable=False`` (static) removes the compaction entirely — the
    grow-mode engines never evict, so their tick is a pure donated
    observe. ``wmax`` (static) is the caller's promise that occupancy
    never exceeds it (a sliding engine's window bounds n): the whole
    tick then runs on the ``[:wmax]`` block of every leaf and splices
    the result back in place, so per-tick cost scales with the *window*,
    not the padded capacity.
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    if wmax is not None and wmax < cap:
        sub = Session(
            OnlineKnnState(knn.X[:wmax], knn.y[:wmax], knn.best[:wmax],
                           knn.n),
            sess.D[:wmax, :wmax])
        sub2, p = _sliding_step(sub, x_new, y_new, tau, window, active,
                                k=k, evictable=evictable)
        return Session(
            OnlineKnnState(
                X=knn.X.at[:wmax].set(sub2.knn.X),
                y=knn.y.at[:wmax].set(sub2.knn.y),
                best=knn.best.at[:wmax].set(sub2.knn.best),
                n=sub2.knn.n,
            ),
            D=sess.D.at[:wmax, :wmax].set(sub2.D)), p
    act = jnp.asarray(active)
    if evictable:
        ev = act & (knn.n >= window)
        s = ev.astype(jnp.int32)
        live = jnp.arange(cap) < knn.n
        dcol = sess.D[:, 0]
        affected = (ev & (knn.y == knn.y[0]) & live
                    & (dcol <= knn.best[:, -1]))

        # conditional compaction: pad each leaf by one (the pad value IS
        # the compaction fill) and take one dynamic slice at offset
        # s ∈ {0, 1} — identity when s == 0, shift-with-fill when s == 1
        X1 = cshift(knn.X, s, 0)
        y1 = cshift(knn.y, s, -1)
        L1 = cshift(knn.best, s, BIG)
        Dp = jnp.pad(sess.D, ((0, 1), (0, 1)), constant_values=BIG)
        D1 = jax.lax.dynamic_slice(Dp, (s, s), (cap, cap))
        aff1 = cshift(affected, s, False)
        es1 = cshift(dcol, s, BIG)
        n1 = knn.n - s
        live1 = jnp.arange(cap) < n1
        cand = (y1[:, None] == y1[None, :]) & live1[None, :]
        best1 = _drop_backfill(L1, es1, cand, D1, aff1, k=k)
    else:
        X1, y1, best1, D1, n1 = knn.X, knn.y, knn.best, sess.D, knn.n

    # price + learn through the same code path as core.online.run_stream
    knn1 = OnlineKnnState(X1, y1, best1, n1)
    knn2, p, d = online.observe_with_dists(knn1, x_new, y_new, tau, k=k)

    # gate on ``active``: the big leaf (D) is written with its own
    # current values on inactive lanes (D is symmetric, so the row at
    # idx equals the column at idx); the small leaves are selects
    idx = n1
    row = jnp.where(act, d, D1[idx, :])
    D2 = D1.at[idx, :].set(row).at[:, idx].set(row)
    knn3 = OnlineKnnState(
        X=jnp.where(act, knn2.X, X1),
        y=jnp.where(act, knn2.y, y1),
        best=jnp.where(act, knn2.best, best1),
        n=jnp.where(act, knn2.n, n1),
    )
    p = jnp.where(act, p, jnp.asarray(jnp.nan, dtype=X1.dtype))
    return Session(knn3, D2), p


def _observe_sliding(sess: Session, x_new, y_new, tau, window, *, k):
    """Evict-if-full then observe: one fixed-shape sliding-window step.

    ``window`` is a traced scalar (per-tenant window sizes never
    retrace). The fused ``_sliding_step`` with every lane active.
    """
    return _sliding_step(sess, x_new, y_new, tau, window, True, k=k)


observe_sliding = functools.partial(
    jax.jit, static_argnames=("k",))(_observe_sliding)
#: Donating form of ``observe_sliding`` — same numerics, input deleted.
observe_sliding_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe_sliding)


def grow(sess: Session, factor: int = 2) -> Session:
    """Double (by default) capacity host-side, preserving all live state.

    Shapes change, so jitted steps retrace — but only O(log n) times over
    a session's lifetime, the capacity-doubling schedule. Not jittable.
    """
    cap = sess.capacity
    extra = cap * (factor - 1)
    knn = sess.knn
    return Session(
        knn=OnlineKnnState(
            X=jnp.pad(knn.X, ((0, extra), (0, 0))),
            y=jnp.pad(knn.y, (0, extra), constant_values=-1),
            best=jnp.pad(knn.best, ((0, extra), (0, 0)),
                         constant_values=BIG),
            n=knn.n,
        ),
        D=jnp.pad(sess.D, ((0, extra), (0, extra)), constant_values=BIG),
    )


@functools.partial(jax.jit, static_argnames=("k", "n_labels"))
def predict_pvalues(sess: Session, X_test, *, k, n_labels):
    """Read-only full-CP query: p-values (m, n_labels) for every label.

    Hot path: candidate scores via one masked top-k, then the fused
    score-update + count through ``kernels.ops.cp_knn_counts`` (the
    Pallas kernel on TPU). Inert rows carry a -BIG sentinel so they are
    never counted regardless of the padded capacity.

    Rows whose k-best list is not full (label rarer than k in the
    window) are excluded from the kernel and counted caller-side: the
    kernel's ``sums - kth + d`` update would subtract the BIG padding
    sentinel and swallow the finite part in f32. The caller-side path
    uses the cancellation-safe ``base + (kth or d)`` form of
    ``measures.knn._updated_scores``, so rare labels stay exact.
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    live = jnp.arange(cap) < knn.n

    d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, knn.X), 0.0))  # (m, cap)
    labels = jnp.arange(n_labels, dtype=knn.y.dtype)
    same = (knn.y[None, :] == labels[:, None]) & live[None, :]  # (l, cap)
    dm = jnp.where(same[None], d[:, None, :], BIG)  # (m, l, cap)
    alpha = jnp.sum(-jax.lax.top_k(-dm, k)[0], axis=-1)  # (m, l)

    kth = knn.best[:, -1]
    full = live & (kth < BIG)  # k-best list fully populated
    sum_same = jnp.where(full, jnp.sum(knn.best, axis=1), -BIG)
    kth_same = jnp.where(full, kth, -BIG)
    counts = kops.cp_knn_counts(
        knn.X, jnp.where(live, knn.y, -1), sum_same, kth_same, X_test,
        alpha, n_labels)

    deficient = live & (kth >= BIG)
    base = jnp.sum(knn.best[:, :-1], axis=1)  # (cap,)
    upd = same[None] & (d[:, None, :] < kth)  # (m, l, cap)
    scores = base + jnp.where(upd, d[:, None, :], kth)
    ge = (scores >= alpha[..., None]) & deficient[None, None, :]
    counts = counts + jnp.sum(ge.astype(counts.dtype), axis=-1)
    return (counts + 1.0) / (knn.n + 1.0)


__all__ = ["Session", "init", "observe", "observe_donated", "evict_oldest",
           "evict_oldest_donated", "observe_sliding",
           "observe_sliding_donated", "grow", "predict_pvalues"]
