"""Per-tenant capacity-padded CP session with exact decremental eviction.

A ``Session`` wraps ``core.online.OnlineKnnState`` (the paper's incremental
simplified-k-NN CP state, Section 9) and adds the one piece the pure
incremental state cannot provide: **exact forgetting**. The paper's
decremental update (Fig. 1 read backwards) removes a training point in
O(n) per affected neighbour list — but a neighbour list that loses its
j-th entry must be backfilled with the (k+1)-th best distance, which the
k-slot state no longer knows. The session therefore maintains the live
pairwise distance matrix ``D`` (built incrementally, one row+column per
``observe`` — the distances are computed once anyway for the p-value), so
eviction backfills from stored exact distances instead of re-deriving
them: bit-exact against fit-from-scratch, no O(n^2 p) recompute.

Invariants (all arrays are capacity-padded, fixed-shape, jit-stable):

* rows ``[0, n)`` are live, in arrival order (row 0 is the oldest);
* ``D[i, j]`` is the Euclidean distance between live rows i and j,
  computed exactly as ``core.online.observe`` computes it
  (``sqrt(max(sum((xi-xj)^2), 0))``); BIG on the diagonal, on inert
  rows/columns, and everywhere eviction has compacted past;
* ``knn.best`` rows always equal what fit-from-scratch on the current
  window would produce (the exactness tests assert this bitwise).

``observe`` delegates the p-value + learn step to
``core.online.observe_with_dists`` so session p-values are bit-identical
to ``core.online.run_stream``; ``evict_oldest`` is the decremental
update; ``grow`` doubles capacity host-side (retraces only O(log n)
times — the capacity-doubling schedule).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import online
from repro.core.online import BIG, OnlineKnnState
from repro.kernels import ops as kops


@jax.tree_util.register_pytree_node_class
@dataclass
class Session:
    """One tenant's sliding-window CP state: k-NN state + live distances."""

    knn: OnlineKnnState  # capacity-padded incremental CP state
    D: jnp.ndarray  # (cap, cap) live pairwise distances, BIG elsewhere

    def tree_flatten(self):
        return ((self.knn, self.D), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.D.shape[-1]


def init(capacity: int, p: int, k: int, dtype=jnp.float32) -> Session:
    if capacity < k:
        raise ValueError(
            f"capacity {capacity} < k {k}: the k-best machinery (top_k) "
            "needs at least k rows")
    return Session(
        knn=online.init(capacity, p, k, dtype=dtype),
        D=jnp.full((capacity, capacity), BIG, dtype=dtype),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def observe(sess: Session, x_new, y_new, tau, *, k):
    """Smoothed p-value for (x_new, y_new), then learn it — one O(cap) step.

    The p-value is bit-identical to ``core.online.observe`` (it *is* that
    computation); additionally the new point's distance row/column is
    recorded in ``D`` for later exact eviction. Precondition: n < capacity
    (callers grow or evict first).
    """
    idx = sess.knn.n
    knn, p, d = online.observe_with_dists(sess.knn, x_new, y_new, tau, k=k)
    D = sess.D.at[idx, :].set(d).at[:, idx].set(d)
    return Session(knn, D), p


@functools.partial(jax.jit, static_argnames=("k",))
def evict_oldest(sess: Session, *, k) -> Session:
    """Exact decremental update: forget the oldest live point.

    Paper's decremental rule: only points whose same-label k-neighbourhood
    contained the evicted point are affected; each backfills from the
    (k+1)-th best — here recovered from the maintained ``D``, so the
    result is bit-exact vs. refitting on the remaining window. Rows are
    compacted down by one to keep the arrival-order invariant.
    Precondition: n >= 1 (guarded by callers; under vmap+select the n=0
    lanes compute garbage that the caller's select discards).
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    live = jnp.arange(cap) < knn.n

    # which survivors held the evicted point in their k-best list?
    # d(i, evicted) <= kth  <=>  it is among i's k smallest same-label
    # distances (tie-robust: removing any one occurrence of a tied value
    # leaves the same remaining multiset, and we recompute from D).
    dcol = sess.D[:, 0]
    kth = knn.best[:, -1]
    affected = (knn.y == knn.y[0]) & live & (dcol <= kth)

    # compact every array down one row (and D one column)
    def shift(a, fill):
        return jnp.concatenate([a[1:], jnp.full_like(a[:1], fill)], axis=0)

    Xs = shift(knn.X, 0)
    ys = shift(knn.y, -1)
    bests = shift(knn.best, BIG)
    Ds = shift(sess.D, BIG)
    Ds = jnp.concatenate(
        [Ds[:, 1:], jnp.full_like(Ds[:, :1], BIG)], axis=1)
    aff = shift(affected, False)

    # backfill affected rows: exact k-best over the remaining window,
    # straight from the stored distances (inert/diagonal entries are BIG)
    n2 = knn.n - 1
    live2 = jnp.arange(cap) < n2
    Dm = jnp.where(
        (ys[:, None] == ys[None, :]) & live2[None, :], Ds, BIG)
    rec = jnp.sort(-jax.lax.top_k(-Dm, k)[0], axis=1)
    best2 = jnp.where(aff[:, None], rec, bests)
    return Session(OnlineKnnState(Xs, ys, best2, n2), Ds)


@functools.partial(jax.jit, static_argnames=("k",))
def observe_sliding(sess: Session, x_new, y_new, tau, window, *, k):
    """Evict-if-full then observe: one fixed-shape sliding-window step.

    ``window`` is a traced scalar (per-tenant window sizes never retrace).
    Under vmap the conds lower to selects — both branches run, lanes that
    don't evict keep their state bitwise unchanged.
    """
    sess = jax.lax.cond(
        sess.knn.n >= window,
        lambda s: evict_oldest(s, k=k),
        lambda s: s,
        sess,
    )
    return observe(sess, x_new, y_new, tau, k=k)


def grow(sess: Session, factor: int = 2) -> Session:
    """Double (by default) capacity host-side, preserving all live state.

    Shapes change, so jitted steps retrace — but only O(log n) times over
    a session's lifetime, the capacity-doubling schedule. Not jittable.
    """
    cap = sess.capacity
    extra = cap * (factor - 1)
    knn = sess.knn
    return Session(
        knn=OnlineKnnState(
            X=jnp.pad(knn.X, ((0, extra), (0, 0))),
            y=jnp.pad(knn.y, (0, extra), constant_values=-1),
            best=jnp.pad(knn.best, ((0, extra), (0, 0)),
                         constant_values=BIG),
            n=knn.n,
        ),
        D=jnp.pad(sess.D, ((0, extra), (0, extra)), constant_values=BIG),
    )


@functools.partial(jax.jit, static_argnames=("k", "n_labels"))
def predict_pvalues(sess: Session, X_test, *, k, n_labels):
    """Read-only full-CP query: p-values (m, n_labels) for every label.

    Hot path: candidate scores via one masked top-k, then the fused
    score-update + count through ``kernels.ops.cp_knn_counts`` (the
    Pallas kernel on TPU). Inert rows carry a -BIG sentinel so they are
    never counted regardless of the padded capacity.

    Rows whose k-best list is not full (label rarer than k in the
    window) are excluded from the kernel and counted caller-side: the
    kernel's ``sums - kth + d`` update would subtract the BIG padding
    sentinel and swallow the finite part in f32. The caller-side path
    uses the cancellation-safe ``base + (kth or d)`` form of
    ``measures.knn._updated_scores``, so rare labels stay exact.
    """
    knn = sess.knn
    cap = knn.X.shape[0]
    live = jnp.arange(cap) < knn.n

    d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, knn.X), 0.0))  # (m, cap)
    labels = jnp.arange(n_labels, dtype=knn.y.dtype)
    same = (knn.y[None, :] == labels[:, None]) & live[None, :]  # (l, cap)
    dm = jnp.where(same[None], d[:, None, :], BIG)  # (m, l, cap)
    alpha = jnp.sum(-jax.lax.top_k(-dm, k)[0], axis=-1)  # (m, l)

    kth = knn.best[:, -1]
    full = live & (kth < BIG)  # k-best list fully populated
    sum_same = jnp.where(full, jnp.sum(knn.best, axis=1), -BIG)
    kth_same = jnp.where(full, kth, -BIG)
    counts = kops.cp_knn_counts(
        knn.X, jnp.where(live, knn.y, -1), sum_same, kth_same, X_test,
        alpha, n_labels)

    deficient = live & (kth >= BIG)
    base = jnp.sum(knn.best[:, :-1], axis=1)  # (cap,)
    upd = same[None] & (d[:, None, :] < kth)  # (m, l, cap)
    scores = base + jnp.where(upd, d[:, None, :], kth)
    ge = (scores >= alpha[..., None]) & deficient[None, None, :]
    counts = counts + jnp.sum(ge.astype(counts.dtype), axis=-1)
    return (counts + 1.0) / (knn.n + 1.0)


__all__ = ["Session", "init", "observe", "evict_oldest", "observe_sliding",
           "grow", "predict_pvalues"]
