"""Tenant lifecycle over capacity-bucketed engine pools.

The engines serve a *fixed* slot grid: ``n_sessions`` lanes, one
capacity. A real fleet has tenants arriving, leaving, and growing at
different rates — and in grow mode one slow-growing tenant filling its
lane forces ``ensure_room`` to double the capacity of EVERY lane in
the engine (a pool-wide retrace plus an O(S·cap²) copy). The fleet
fixes that with the classic serving move: group tenants into pools by
*capacity bucket* and migrate a tenant to the next bucket's pool as it
grows, so growth costs one O(cap²) lane copy instead of a pool-wide
retrace.

Bucket boundaries come from the fitted cost model when one is
available (``CostModel.suggest_buckets`` — geometric in modeled
per-tick *cost*, replacing the static power-of-two
``telemetry.tracer.capacity_bucket`` scheme); without a model the
power-of-two ladder is the fallback. Each pool is one ordinary
``ServingEngine`` / ``RegressionServingEngine`` (grow mode, donated,
optionally tenant-sharded across devices via ``shards``), so every
exactness property those engines carry transfers: a fleet-served
tenant's p-value stream is bit-identical to a dedicated single-lane
engine fed the same stream (tested), because p-values are
capacity-padding-invariant and migration is exactly the engines'
proven ``grow`` transformation generalized to an arbitrary target
capacity (normalize the ring to linear order, then pad every leaf
with its inert fill).

    fleet = Fleet(dim=8, k=5, n_labels=2)
    fleet.admit("alice"); fleet.admit("bob")
    ps = fleet.observe({"alice": (x_a, y_a, tau_a),
                        "bob": (x_b, y_b, tau_b)})
    sets = fleet.predict("alice", X_query)       # (m, n_labels)
    fleet.retire("bob")                          # lane returns to the pool

Tenants past the last bucket boundary stay in the last pool and let
its engine auto-grow (the pre-fleet behavior, now confined to the
tenants that actually need it).
"""
from __future__ import annotations

import bisect
from typing import Any

import jax
import jax.numpy as jnp

from repro.regression import stream as reg_stream
from repro.regression.engine import RegressionServingEngine
from repro.regression.stream import RegStreamState
from repro.serving import session as cls_sess_m
from repro.serving.engine import ServingEngine
from repro.serving.session import Session


def pow2_buckets(cap_min: int, cap_max: int) -> list[int]:
    """The static power-of-two bucket ladder (the no-cost-model
    fallback, and what ``suggest_buckets`` reproduces under linear
    cost scaling)."""
    bounds = [int(cap_min)]
    while bounds[-1] < cap_max:
        bounds.append(min(bounds[-1] * 2, int(cap_max)))
    return bounds


def repad_cls(sess: Session, new_cap: int) -> Session:
    """``serving.session.grow`` to an arbitrary target capacity."""
    from repro.core.online import BIG, OnlineKnnState

    extra = new_cap - sess.capacity
    sess = cls_sess_m.to_linear(sess)
    knn = sess.knn
    return Session(
        knn=OnlineKnnState(
            X=jnp.pad(knn.X, ((0, extra), (0, 0))),
            y=jnp.pad(knn.y, (0, extra), constant_values=-1),
            best=jnp.pad(knn.best, ((0, extra), (0, 0)),
                         constant_values=BIG),
            n=knn.n,
        ),
        D=jnp.pad(sess.D, ((0, extra), (0, extra)), constant_values=BIG),
        head=sess.head,
        aid=jnp.pad(sess.aid, (0, extra)),
        wrap=jnp.int32(new_cap),
    )


def repad_reg(state: RegStreamState, new_cap: int) -> RegStreamState:
    """``regression.session.grow`` to an arbitrary target capacity."""
    from repro.core.regression import BIG

    extra = new_cap - state.capacity
    state = reg_stream.to_linear(state)
    return RegStreamState(
        X=jnp.pad(state.X, ((0, extra), (0, 0))),
        y=jnp.pad(state.y, (0, extra)),
        D=jnp.pad(state.D, ((0, extra), (0, extra)), constant_values=BIG),
        nbr_d=jnp.pad(state.nbr_d, ((0, extra), (0, 0)),
                      constant_values=BIG),
        nbr_y=jnp.pad(state.nbr_y, ((0, extra), (0, 0))),
        n=state.n,
        head=state.head,
        aid=jnp.pad(state.aid, (0, extra)),
        wrap=jnp.int32(new_cap),
        nbr_a=jnp.pad(state.nbr_a, ((0, extra), (0, 0))),
    )


class _Pool:
    """One engine + its state + lane bookkeeping at one capacity."""

    def __init__(self, fleet: "Fleet", capacity: int, index: int):
        self.capacity = capacity
        self.index = index
        self.engine = fleet._make_engine(capacity)
        self.state = self.engine.init_state()
        S = self.engine.n_sessions
        self.free: list[int] = list(range(S - 1, -1, -1))
        self.lane_tenant: dict[int, Any] = {}

    def set_lane(self, lane: int, lane_state) -> None:
        """Scatter one session tree into the stacked state (host-side
        rare path: O(S·cap²) copy, like the engines' own ``grow``)."""
        self.state = jax.tree_util.tree_map(
            lambda L, v: L.at[lane].set(v.astype(L.dtype)), self.state,
            lane_state)
        self.state = self.engine._shard_state(self.state)
        self.engine.reset_occupancy()

    def get_lane(self, lane: int):
        return jax.tree_util.tree_map(lambda L: L[lane], self.state)


class Fleet:
    """Admit / observe / retire tenants across bucketed engine pools.

    Parameters
    ----------
    dim, k, n_labels, dtype: per-tenant CP geometry (``n_labels`` only
                read in classification mode).
    mode:       "classification" (``ServingEngine``) or "regression"
                (``RegressionServingEngine``). All pools run grow mode
                (window=None) — bucketing exists to absorb growth.
    cost_model: optional fitted ``telemetry.costmodel.CostModel``;
                bucket boundaries come from its ``suggest_buckets``
                (cost-geometric). ``None`` => power-of-two ladder.
    cap_min, cap_max: the bucket range; ``cap_min`` is every new
                tenant's starting capacity (must be >= k).
    cost_ratio: per-bucket top-vs-bottom modeled-cost ratio for
                ``suggest_buckets``.
    pool_sessions: lanes per pool engine (rounded up to a multiple of
                ``shards``); a full pool just spills into a sibling.
    shards:     tenant-shard every pool engine across this many devices.
    metrics:    optional ``MetricsRegistry`` for fleet counters/gauges.
    guard:      admission-check observe inputs host-side (features
                finite, label in range, tau in [0, 1]); a rejected
                tenant's tick is never dispatched — its state stays
                bitwise unchanged and it gets a NaN p-value back
                (``fleet_rejected_observes_total``). The in-graph
                equivalent for raw engines is
                ``robustness.guard.TickGuard``.
    """

    def __init__(self, *, dim: int, k: int, n_labels: int = 2,
                 mode: str = "classification", cost_model=None,
                 cap_min: int = 32, cap_max: int = 4096,
                 cost_ratio: float = 2.0, pool_sessions: int = 64,
                 dtype=jnp.float32, shards: int = 1, metrics=None,
                 guard: bool = False):
        if mode not in ("classification", "regression"):
            raise ValueError(f"unknown fleet mode {mode!r}")
        if cap_min < k:
            raise ValueError(f"cap_min {cap_min} < k {k}")
        self.dim = dim
        self.k = k
        self.n_labels = n_labels
        self.mode = mode
        self.dtype = dtype
        self.shards = shards
        self.pool_sessions = -(-pool_sessions // shards) * shards
        self.metrics = metrics
        self.guard = guard
        if cost_model is not None:
            self.buckets = cost_model.suggest_buckets(
                cap_min=cap_min, cap_max=cap_max, cost_ratio=cost_ratio,
                engine=mode)
        else:
            self.buckets = pow2_buckets(cap_min, cap_max)
        self._pools: dict[int, list[_Pool]] = {}
        self._where: dict[Any, tuple[int, int, int]] = {}  # cap, pool, lane
        self._occ: dict[Any, int] = {}
        self._init_lane_cache: dict[int, Any] = {}

    # -- engine/pool plumbing -----------------------------------------------

    def _make_engine(self, capacity: int):
        kw = dict(n_sessions=self.pool_sessions, capacity=capacity,
                  dim=self.dim, k=self.k, window=None, dtype=self.dtype,
                  shards=self.shards)
        if self.mode == "classification":
            return ServingEngine(n_labels=self.n_labels, **kw)
        return RegressionServingEngine(**kw)

    def _init_lane(self, capacity: int):
        lane = self._init_lane_cache.get(capacity)
        if lane is None:
            m = cls_sess_m if self.mode == "classification" else reg_stream
            lane = m.init(capacity, self.dim, self.k, dtype=self.dtype)
            self._init_lane_cache[capacity] = lane
        return lane

    def _alloc(self, capacity: int) -> tuple[_Pool, int]:
        pools = self._pools.setdefault(capacity, [])
        for pool in pools:
            if pool.free:
                return pool, pool.free.pop()
        pool = _Pool(self, capacity, len(pools))
        pools.append(pool)
        if self.metrics is not None:
            self.metrics.gauge("fleet_pools", mode=self.mode).set(
                sum(len(ps) for ps in self._pools.values()))
        return pool, pool.free.pop()

    def _counter(self, name: str):
        if self.metrics is not None:
            self.metrics.counter(name, mode=self.mode).inc()

    def _set_tenants_gauge(self) -> None:
        if self.metrics is not None:
            self.metrics.gauge("fleet_tenants", mode=self.mode).set(
                len(self._where))

    # -- lifecycle ----------------------------------------------------------

    def admit(self, tid) -> None:
        """Give ``tid`` a fresh lane in the smallest-capacity pool."""
        if tid in self._where:
            raise KeyError(f"tenant {tid!r} already admitted")
        cap = self.buckets[0]
        pool, lane = self._alloc(cap)
        # free lanes are always init-fresh (retire/migrate reset them
        # eagerly), so admission is O(1) host bookkeeping
        pool.lane_tenant[lane] = tid
        self._where[tid] = (cap, pool.index, lane)
        self._occ[tid] = 0
        self._counter("fleet_admissions_total")
        self._set_tenants_gauge()

    def retire(self, tid) -> None:
        """Return ``tid``'s lane to its pool (state cleared)."""
        cap, pi, lane = self._where.pop(tid)
        pool = self._pools[cap][pi]
        del pool.lane_tenant[lane]
        del self._occ[tid]
        # eager reset: a stale full lane would otherwise count toward
        # the pool's grow-mode occupancy bound and retrace the pool
        # (engine.capacity, not the bucket key — the last pool may have
        # auto-grown past its boundary)
        pool.set_lane(lane, self._init_lane(pool.engine.capacity))
        pool.free.append(lane)
        self._counter("fleet_retirements_total")
        self._set_tenants_gauge()

    def _migrate(self, tid, needed: int) -> None:
        """Move ``tid`` to the smallest bucket holding ``needed`` points
        — one lane repad (the engines' ``grow``, arbitrary target cap)
        instead of a pool-wide retrace."""
        src_cap, spi, slane = self._where[tid]
        i = bisect.bisect_left(self.buckets, needed)
        new_cap = self.buckets[min(i, len(self.buckets) - 1)]
        if new_cap <= src_cap:
            return
        src_pool = self._pools[src_cap][spi]
        repad = (repad_cls if self.mode == "classification"
                 else repad_reg)
        lane_state = repad(src_pool.get_lane(slane), new_cap)
        del src_pool.lane_tenant[slane]
        src_pool.set_lane(slane, self._init_lane(src_pool.engine.capacity))
        src_pool.free.append(slane)
        pool, lane = self._alloc(new_cap)
        pool.set_lane(lane, lane_state)
        pool.lane_tenant[lane] = tid
        self._where[tid] = (new_cap, pool.index, lane)
        self._counter("fleet_migrations_total")

    # -- serving ------------------------------------------------------------

    def observe(self, items: dict[Any, tuple]) -> dict[Any, jnp.ndarray]:
        """One fleet tick: ``items`` maps tid -> (x, y, tau).

        Tenants about to outgrow their pool migrate first (so
        ``ensure_room`` never doubles a whole pool on their account —
        only past the last bucket does the old auto-grow fire), then
        each pool with traffic runs ONE engine tick with the other
        lanes masked inactive. Returns tid -> p-value (0-d jax array,
        still async; ``float()`` to sync). With ``guard=True`` a
        malformed item is rejected before dispatch (NaN p, state
        untouched, occupancy unchanged).
        """
        import numpy as np

        if self.guard:
            live = {}
            out_rej: dict[Any, jnp.ndarray] = {}
            for tid, (x, y, tau) in items.items():
                ok = bool(np.all(np.isfinite(
                    np.asarray(x, dtype=np.float64))))
                yf = float(np.asarray(y).astype(np.float64))
                if self.mode == "classification":
                    ok = (ok and np.isfinite(yf)
                          and 0 <= int(yf) < self.n_labels)
                else:
                    ok = ok and bool(np.isfinite(yf))
                tau_f = float(tau)
                ok = ok and bool(np.isfinite(tau_f)) and 0.0 <= tau_f <= 1.0
                if ok:
                    live[tid] = (x, y, tau)
                else:
                    self._counter("fleet_rejected_observes_total")
                    out_rej[tid] = jnp.asarray(float("nan"),
                                               dtype=self.dtype)
            if out_rej:
                items = live
                out_rej.update(self._observe_live(items))
                return out_rej
            items = live
        return self._observe_live(items)

    def _observe_live(self, items: dict[Any, tuple]) -> dict[Any, jnp.ndarray]:
        last = self.buckets[-1]
        for tid in items:
            cap, _, _ = self._where[tid]
            if self._occ[tid] + 1 > cap and cap < last:
                self._migrate(tid, self._occ[tid] + 1)
        groups: dict[tuple[int, int], dict[int, tuple]] = {}
        for tid, (x, y, tau) in items.items():
            cap, pi, lane = self._where[tid]
            groups.setdefault((cap, pi), {})[lane] = (tid, x, y, tau)
        import numpy as np

        out: dict[Any, jnp.ndarray] = {}
        for (cap, pi), lanes in sorted(groups.items()):
            pool = self._pools[cap][pi]
            S = pool.engine.n_sessions
            ydt = np.int32 if self.mode == "classification" else self.dtype
            xs = np.zeros((S, self.dim), dtype=self.dtype)
            ys = np.zeros((S,), dtype=ydt)
            taus = np.zeros((S,), dtype=self.dtype)
            act = np.zeros((S,), dtype=bool)
            for lane, (tid, x, y, tau) in lanes.items():
                xs[lane] = np.asarray(x)
                ys[lane] = y
                taus[lane] = tau
                act[lane] = True
            pool.state, p = pool.engine.observe(
                pool.state, jnp.asarray(xs), jnp.asarray(ys),
                jnp.asarray(taus), active=jnp.asarray(act))
            for lane, (tid, _, _, _) in lanes.items():
                out[tid] = p[lane]
                self._occ[tid] += 1
        return out

    def _lane_of(self, tid) -> tuple[_Pool, int]:
        cap, pi, lane = self._where[tid]
        return self._pools[cap][pi], lane

    def predict(self, tid, X_test) -> jnp.ndarray:
        """Classification full-CP p-values (m, n_labels) for one tenant."""
        pool, lane = self._lane_of(tid)
        return pool.engine.predict(pool.state, X_test)[lane]

    def intervals(self, tid, X_test, epsilon: float) -> jnp.ndarray:
        """Regression prediction intervals (m, 2) for one tenant."""
        pool, lane = self._lane_of(tid)
        return pool.engine.intervals(pool.state, X_test, epsilon)[lane]

    def pvalues(self, tid, X_test, t_query) -> jnp.ndarray:
        """Regression p-values (m, nq) for one tenant."""
        pool, lane = self._lane_of(tid)
        return pool.engine.pvalues(pool.state, X_test, t_query)[lane]

    # -- introspection ------------------------------------------------------

    def occupancy(self, tid) -> int:
        """Host-tracked live-point count (exact in grow mode)."""
        return self._occ[tid]

    def stats(self) -> dict[str, Any]:
        """Host-side fleet snapshot; publishes pool occupancy gauges."""
        pools = []
        for cap in sorted(self._pools):
            for pool in self._pools[cap]:
                used = len(pool.lane_tenant)
                occ = [self._occ[t] for t in pool.lane_tenant.values()]
                pools.append({
                    "capacity": cap,
                    "pool": pool.index,
                    "lanes": pool.engine.n_sessions,
                    "lanes_used": used,
                    "occupancy_max": max(occ, default=0),
                    "occupancy_mean": (sum(occ) / used) if used else 0.0,
                })
                if self.metrics is not None:
                    self.metrics.gauge(
                        "fleet_pool_lanes_used", mode=self.mode,
                        capacity=cap, pool=pool.index).set(used)
        return {"tenants": len(self._where), "buckets": self.buckets,
                "pools": pools}


# historic private names (pre-robustness); the guard's lane-restore and
# external callers use the public ones
_repad_cls = repad_cls
_repad_reg = repad_reg

__all__ = ["Fleet", "pow2_buckets", "repad_cls", "repad_reg"]
