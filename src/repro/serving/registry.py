"""Declarative nonconformity-measure registry for online CP serving.

Unifies the paper's incrementally-and-decrementally optimized measures —
k-NN / simplified k-NN (Section 3), KDE (Section 4), LS-SVM (Section 5),
bootstrap (Section 6, Algorithm 3), streaming k-NN regression
(Section 8.1) — behind one ``fit / observe /
evict / pvalues`` surface (the Predictor–Calibrator shape of wrapper
libraries like puncc), so a new measure plugs into the serving stack by
registering four functions instead of editing engine code (regression
measures add an optional ``intervals`` hook)::

    from repro.serving import registry

    cp = registry.ConformalPredictor("kde", h=0.8, n_labels=3)
    cp.fit(X, y)
    cp.observe(x_new, y_new)      # paper's incremental update, O(n)
    cp.evict(0)                   # paper's decremental update, O(n)
    p = cp.pvalues(X_test)        # (m, n_labels) full-CP p-values

Registering a custom measure::

    registry.register(registry.MeasureSpec(
        name="my_measure",
        fit=lambda X, y, hp: (my_fit(X, y), None),
        observe=lambda st, ctx, x, y, hp: my_add(st, x, y),
        evict=lambda st, ctx, i, hp: my_remove(st, i),
        pvalues=lambda st, ctx, Xt, hp: my_pvalues(st, Xt),
        defaults={"n_labels": 2},
    ))

``fit`` returns ``(state, ctx)`` — ``ctx`` carries non-pytree companions
(e.g. the LS-SVM feature map closure); every other hook receives it
back. These predictors are the exact-shape API (arrays grow/shrink per
update, one retrace per size); the fixed-shape vmapped serving form is
``repro.serving.session`` / ``engine``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import pvalues as pv
from repro.core.measures import kde as kde_m
from repro.core.measures import knn as knn_m
from repro.core.measures import lssvm as lssvm_m


@dataclass(frozen=True)
class MeasureSpec:
    """One pluggable nonconformity measure (all hooks take the hp dict)."""

    name: str
    fit: Callable[..., tuple[Any, Any]]  # (X, y, hp) -> (state, ctx)
    observe: Callable[..., Any]  # (state, ctx, x, y, hp) -> state
    evict: Callable[..., Any] | None  # (state, ctx, i, hp) -> state
    pvalues: Callable[..., jnp.ndarray]  # (state, ctx, X_test, hp) -> (m, l)
    defaults: dict
    # regression measures: (state, ctx, X_test, epsilon, hp) -> (m, 2)
    intervals: Callable[..., jnp.ndarray] | None = None


_REGISTRY: dict[str, MeasureSpec] = {}


def register(spec: MeasureSpec) -> MeasureSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> MeasureSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown measure {name!r}; registered: {available()}") from None


def available() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in measures
# ---------------------------------------------------------------------------


def _knn_spec(name: str, simplified: bool) -> MeasureSpec:
    def fit(X, y, hp):
        return knn_m.fit(X, y.astype(jnp.int32), k=hp["k"]), None

    def observe(state, ctx, x, y, hp):
        return knn_m.incremental_add(state, x, int(y), k=hp["k"])

    def evict(state, ctx, i, hp):
        return knn_m.decremental_remove(state, i, k=hp["k"])

    def pvalues(state, ctx, X_test, hp):
        return knn_m.pvalues_optimized(
            state, X_test, k=hp["k"], simplified=simplified,
            n_labels=hp["n_labels"])

    return MeasureSpec(name, fit, observe, evict, pvalues,
                       defaults={"k": 7, "n_labels": 2})


def _kde_spec() -> MeasureSpec:
    def fit(X, y, hp):
        return kde_m.fit(X, y.astype(jnp.int32), h=hp["h"],
                         n_labels=hp["n_labels"]), None

    def observe(state, ctx, x, y, hp):
        return kde_m.incremental_add(state, x, int(y), h=hp["h"])

    def evict(state, ctx, i, hp):
        return kde_m.decremental_remove(state, i, h=hp["h"])

    def pvalues(state, ctx, X_test, hp):
        return kde_m.pvalues_optimized(
            state, X_test, h=hp["h"], p_dim=state.X.shape[1],
            n_labels=hp["n_labels"])

    return MeasureSpec("kde", fit, observe, evict, pvalues,
                       defaults={"h": 1.0, "n_labels": 2})


def _lssvm_spec() -> MeasureSpec:
    # binary measure: int labels {0, 1} are mapped to {-1, +1}

    def fit(X, y, hp):
        if hp["n_labels"] != 2:
            raise ValueError(
                "lssvm measure is binary (labels {0, 1}); use one-vs-rest "
                "for more labels (paper Section 5)")
        y = jnp.asarray(y)
        if not bool(jnp.all((y == 0) | (y == 1))):
            raise ValueError("lssvm measure expects labels in {0, 1}")
        phi, _ = lssvm_m.feature_map(
            hp["feature_map"], X.shape[1], hp["rff_dim"], hp["seed"])
        Y = 2.0 * y.astype(jnp.float32) - 1.0
        return lssvm_m.fit(phi(X), Y, hp["rho"]), phi

    def observe(state, phi, x, y, hp):
        y = int(y)
        if y not in (0, 1):
            raise ValueError("lssvm measure expects labels in {0, 1}")
        return lssvm_m.incremental_add(
            state, phi(x[None])[0], 2.0 * jnp.float32(y) - 1.0)

    def evict(state, phi, i, hp):
        return lssvm_m.decremental_remove(state, i)

    def pvalues(state, phi, X_test, hp):
        return lssvm_m.pvalues_optimized(state, phi(X_test))

    return MeasureSpec("lssvm", fit, observe, evict, pvalues,
                       defaults={"rho": 1.0, "feature_map": "linear",
                                 "rff_dim": 128, "seed": 0, "n_labels": 2})


def _knn_regression_spec() -> MeasureSpec:
    """Streaming k-NN regression CP (paper Section 8.1).

    The state is an exact-shape ``regression.RegStreamState`` (capacity ==
    n; one retrace per size, like every registry measure). ``pvalues``
    evaluates p(t) at the ``t_query`` label grid; the ``intervals`` hook
    is the natural read path.
    """
    from repro.regression import session as rsession
    from repro.regression import stream as rstream

    def _pad_one(state):
        # registry states stay linear (head == 0, ring never wraps), so
        # growing/shrinking capacity just tracks the ring modulus along
        return rstream.RegStreamState(
            X=jnp.pad(state.X, ((0, 1), (0, 0))),
            y=jnp.pad(state.y, (0, 1)),
            D=jnp.pad(state.D, ((0, 1), (0, 1)), constant_values=1e30),
            nbr_d=jnp.pad(state.nbr_d, ((0, 1), (0, 0)),
                          constant_values=1e30),
            nbr_y=jnp.pad(state.nbr_y, ((0, 1), (0, 0))),
            n=state.n,
            head=state.head,
            aid=jnp.pad(state.aid, (0, 1)),
            wrap=state.wrap + 1,
            nbr_a=jnp.pad(state.nbr_a, ((0, 1), (0, 0))),
        )

    def _shrink_one(state):
        return rstream.RegStreamState(
            X=state.X[:-1], y=state.y[:-1], D=state.D[:-1, :-1],
            nbr_d=state.nbr_d[:-1], nbr_y=state.nbr_y[:-1], n=state.n,
            head=state.head, aid=state.aid[:-1], wrap=state.wrap - 1,
            nbr_a=state.nbr_a[:-1])

    def fit(X, y, hp):
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        return rstream.from_fit(X, y, k=hp["k"], capacity=X.shape[0]), None

    def observe(state, ctx, x, y, hp):
        st, _ = rstream.observe(
            _pad_one(state), jnp.asarray(x, jnp.float32),
            jnp.asarray(y, jnp.float32), k=hp["k"])
        return st

    def evict(state, ctx, i, hp):
        n = int(state.n)
        i = int(i)
        if not -n <= i < n:
            raise IndexError(
                f"index {i} out of range for {n} training points")
        return _shrink_one(rstream.evict(state, i % n, k=hp["k"]))

    def pvalues(state, ctx, X_test, hp):
        if hp["t_query"] is None:
            raise ValueError(
                "knn_regression p-values need a label grid: pass "
                "t_query=<array> (or use .intervals(X_test, eps))")
        return rsession.pvalues(
            state, X_test, jnp.asarray(hp["t_query"], jnp.float32),
            k=hp["k"])

    def intervals(state, ctx, X_test, epsilon, hp):
        return rsession.intervals(
            state, X_test, k=hp["k"], epsilon=float(epsilon))

    return MeasureSpec("knn_regression", fit, observe, evict, pvalues,
                       defaults={"k": 7, "t_query": None},
                       intervals=intervals)


def _bootstrap_spec() -> MeasureSpec:
    """Bootstrap CP (paper Section 6, Algorithm 3) served online.

    The state is the host-side shared-sample-pool ``BootstrapState``;
    ``ctx`` is the measure's keyed ``DrawStream`` — the RNG stream that
    ``observe``/``evict`` consume for fresh bootstrap draws (keyed by
    draw id, so identical histories give identical states). Observe
    oversamples for the new point; evict retires every sample containing
    the removed point and backfills — both exact vs. a from-scratch
    build on the same effective sample set (``bootstrap.rebuild``).
    """
    import numpy as np

    from repro.core.measures import bootstrap as boot_m

    def fit(X, y, hp):
        stream = boot_m.DrawStream(hp["seed"])
        state = boot_m.fit(
            np.asarray(X, np.float32), np.asarray(y, np.int32),
            n_labels=hp["n_labels"], B=hp["B"], depth=hp["depth"],
            seed=hp["seed"], max_bprime=hp["max_bprime"], stream=stream)
        return state, stream

    def observe(state, stream, x, y, hp):
        return boot_m.incremental_add(
            state, np.asarray(x, np.float32), int(y), stream=stream)

    def evict(state, stream, i, hp):
        return boot_m.decremental_remove(state, int(i), stream=stream)

    def pvalues(state, stream, X_test, hp):
        return jnp.asarray(
            boot_m.pvalues_optimized(state, np.asarray(X_test)),
            jnp.float32)

    return MeasureSpec("bootstrap", fit, observe, evict, pvalues,
                       defaults={"n_labels": 2, "B": 10, "depth": 5,
                                 "seed": 0, "max_bprime": 100000})


register(_knn_spec("knn", simplified=False))
register(_knn_spec("simplified_knn", simplified=True))
register(_kde_spec())
register(_lssvm_spec())
register(_knn_regression_spec())
register(_bootstrap_spec())


# ---------------------------------------------------------------------------
# unified predictor
# ---------------------------------------------------------------------------


class ConformalPredictor:
    """Stateful full-CP predictor over any registered measure."""

    def __init__(self, measure: str = "simplified_knn", **hyperparams):
        self.spec = get(measure)
        unknown = set(hyperparams) - set(self.spec.defaults)
        if unknown:
            raise TypeError(
                f"{measure}: unknown hyperparameters {sorted(unknown)}; "
                f"accepts {sorted(self.spec.defaults)}")
        self.hp = {**self.spec.defaults, **hyperparams}
        self._state = None
        self._ctx = None

    def fit(self, X, y) -> "ConformalPredictor":
        self._state, self._ctx = self.spec.fit(
            jnp.asarray(X), jnp.asarray(y), self.hp)
        return self

    def observe(self, x, y) -> "ConformalPredictor":
        """Learn one example (paper's incremental update)."""
        self._state = self.spec.observe(
            self._state, self._ctx, jnp.asarray(x), y, self.hp)
        return self

    def evict(self, i: int = 0) -> "ConformalPredictor":
        """Forget training point ``i`` (paper's decremental update)."""
        if self.spec.evict is None:
            raise NotImplementedError(
                f"measure {self.spec.name!r} has no decremental update")
        self._state = self.spec.evict(self._state, self._ctx, i, self.hp)
        return self

    def pvalues(self, X_test) -> jnp.ndarray:
        return self.spec.pvalues(
            self._state, self._ctx, jnp.asarray(X_test), self.hp)

    def predict_set(self, X_test, eps: float) -> jnp.ndarray:
        return pv.prediction_sets(self.pvalues(X_test), eps)

    def intervals(self, X_test, eps: float) -> jnp.ndarray:
        """Prediction intervals (m, 2) — regression measures only."""
        if self.spec.intervals is None:
            raise NotImplementedError(
                f"measure {self.spec.name!r} has no interval read path "
                "(classification measures predict sets; see predict_set)")
        return self.spec.intervals(
            self._state, self._ctx, jnp.asarray(X_test), eps, self.hp)

    @property
    def n(self) -> int:
        """Current training-set size (leading dim of the state's first
        leaf — holds for every built-in and custom pytree state)."""
        return int(jax.tree_util.tree_leaves(self._state)[0].shape[0])


__all__ = ["MeasureSpec", "ConformalPredictor", "register", "get",
           "available"]
