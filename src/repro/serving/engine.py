"""Micro-batching multi-tenant online CP engine.

Batches many per-tenant ``serving.session.Session``s into one stacked
pytree (leading axis = session slot) and advances them all with a single
fixed-shape jitted ``vmap`` step — the serving form of the paper's O(n)
online update: one device dispatch per tick regardless of tenant count,
no retracing as windows fill, slide, or tenants come and go.

Usage::

    from repro.serving.engine import ServingEngine

    eng = ServingEngine(n_sessions=64, capacity=256, dim=16, k=7,
                        n_labels=2, window=128)
    state = eng.init_state()
    for t in range(T):                      # one micro-batch per tick
        x_t, y_t = traffic_at(t)            # (64, 16), (64,)
        tau_t = eng.taus(jax.random.PRNGKey(t))
        state, pvals = eng.observe(state, x_t, y_t, tau_t)  # (64,) smoothed
    sets = eng.predict(state, x_query)      # (64, m, n_labels) full-CP query

Per-session p-values are bit-identical to running that session's stream
through ``core.online.run_stream`` alone (tested); sliding-window
eviction is the exact decremental update of ``serving.session``. The
read-only ``predict`` routes score-update + counting through the fused
Pallas kernel (``kernels/cp_update.py``) on TPU.

Tenants with no traffic on a tick are masked via ``active`` (state
bitwise unchanged, NaN p-value) — the micro-batch shape never changes.
When no ``window`` is set the engine auto-grows: once any session hits
capacity, every array doubles (host-side, O(log n) retraces total).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.serving import session as sess_m
from repro.serving.session import Session


def _session_step(sess, x, y, tau, window, active, *, k):
    def do(s):
        return sess_m.observe_sliding(s, x, y, tau, window, k=k)

    def skip(s):
        return s, jnp.asarray(jnp.nan, dtype=s.knn.X.dtype)

    return jax.lax.cond(active, do, skip, sess)


class ServingEngine:
    """Fixed-slot, fixed-shape multi-tenant CP serving engine.

    Parameters
    ----------
    n_sessions: number of tenant slots (the micro-batch width).
    capacity:   per-session padded training capacity.
    dim:        feature dimension.
    k:          k-NN neighbourhood size (paper's simplified k-NN measure).
    n_labels:   label alphabet for ``predict``.
    window:     sliding-window length (<= capacity); None => grow mode
                (capacity doubles when full instead of evicting).
    """

    def __init__(self, *, n_sessions: int, capacity: int, dim: int, k: int,
                 n_labels: int = 2, window: int | None = None,
                 dtype=jnp.float32):
        if window is not None and window > capacity:
            raise ValueError(f"window {window} exceeds capacity {capacity}")
        if window is not None and window < 1:
            raise ValueError("window must be >= 1")
        if capacity < k:
            raise ValueError(f"capacity {capacity} < k {k}")
        self.n_sessions = n_sessions
        self.capacity = capacity
        self.dim = dim
        self.k = k
        self.n_labels = n_labels
        self.window = window
        self.dtype = dtype
        step = functools.partial(_session_step, k=k)
        self._step = jax.jit(
            jax.vmap(step, in_axes=(0, 0, 0, 0, 0, 0)))
        self._predict = jax.jit(jax.vmap(functools.partial(
            sess_m.predict_pvalues, k=k, n_labels=n_labels)))
        # host-side upper bound on max_s n_s, for grow-mode occupancy
        # checks without a per-tick device sync
        self._n_bound: int | None = None

    # -- state --------------------------------------------------------------

    def init_state(self) -> Session:
        """Stacked Session pytree with a leading (n_sessions,) axis."""
        one = sess_m.init(self.capacity, self.dim, self.k, dtype=self.dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (self.n_sessions,) + a.shape),
            one)

    def taus(self, key) -> jnp.ndarray:
        """One tie-breaking uniform per session slot for this tick."""
        return jax.random.uniform(key, (self.n_sessions,), dtype=self.dtype)

    def _windows(self, state: Session) -> jnp.ndarray:
        cap = state.capacity
        w = cap + 1 if self.window is None else self.window  # +1: never evict
        return jnp.full((self.n_sessions,), w, dtype=jnp.int32)

    # -- serving ------------------------------------------------------------

    def observe(self, state: Session, x, y, tau, active=None):
        """One micro-batched tick: learn (x[s], y[s]) in every active slot.

        x: (S, dim); y: (S,); tau: (S,) tie-break uniforms; active: (S,)
        bool (default all). Returns (state, pvalues (S,)) — NaN p-value on
        inactive slots. In grow mode, auto-doubles capacity first if any
        session is full (host-side sync + retrace, O(log n) times total).
        """
        if active is None:
            active = jnp.ones((self.n_sessions,), dtype=bool)
        if self.window is None:
            # n grows by at most 1 per tick, so a host counter upper-bounds
            # occupancy; the true max is synced only at startup and when
            # the bound reaches capacity (after external state swaps, call
            # reset_occupancy to re-sync).
            cap = state.capacity
            if self._n_bound is None or self._n_bound >= cap:
                self._n_bound = int(jnp.max(state.knn.n))
                while self._n_bound >= cap:
                    state = self.grow(state)
                    cap = state.capacity
            self._n_bound += 1
        return self._step(state, x, y.astype(jnp.int32),
                          tau.astype(self.dtype), self._windows(state),
                          active)

    def reset_occupancy(self) -> None:
        """Forget the host-side occupancy bound (grow mode); the next
        ``observe`` re-syncs it from device. Call after substituting a
        state that this engine did not produce."""
        self._n_bound = None

    def grow(self, state: Session, factor: int = 2) -> Session:
        """Double every session's capacity (host-side, preserves state).

        ``self.capacity`` follows the grown state so ``meta()`` and
        ``init_state()`` stay consistent with the states this engine
        produces."""
        out = jax.vmap(functools.partial(sess_m.grow, factor=factor))(state)
        self.capacity = out.capacity
        return out

    def predict(self, state: Session, X_test) -> jnp.ndarray:
        """Read-only full-CP p-values per session: (S, m, n_labels).

        X_test: (S, m, dim) per-session query batch, or (m, dim) broadcast
        to every session. One vmapped jitted dispatch for all sessions;
        inside it the fused kernel (Pallas on TPU) does the score update
        + count in a single pass.
        """
        if X_test.ndim == 2:
            X_test = jnp.broadcast_to(
                X_test, (self.n_sessions,) + X_test.shape)
        return self._predict(state, X_test)

    # -- snapshot -----------------------------------------------------------

    def meta(self) -> dict[str, Any]:
        """JSON-serializable engine config, stored alongside snapshots."""
        return {
            "n_sessions": self.n_sessions,
            "capacity": self.capacity,
            "dim": self.dim,
            "k": self.k,
            "n_labels": self.n_labels,
            "window": self.window,
            "dtype": jnp.dtype(self.dtype).name,
        }

    @classmethod
    def from_meta(cls, meta: dict[str, Any]) -> "ServingEngine":
        meta = dict(meta)
        meta["dtype"] = jnp.dtype(meta.get("dtype", "float32"))
        return cls(**meta)


__all__ = ["ServingEngine"]
