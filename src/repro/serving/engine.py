"""Micro-batching multi-tenant online CP engine.

Batches many per-tenant ``serving.session.Session``s into one stacked
pytree (leading axis = session slot) and advances them all with a single
fixed-shape jitted ``vmap`` step — the serving form of the paper's O(n)
online update: one device dispatch per tick regardless of tenant count,
no retracing as windows fill, slide, or tenants come and go.

Usage::

    from repro.serving.engine import ServingEngine

    eng = ServingEngine(n_sessions=64, capacity=256, dim=16, k=7,
                        n_labels=2, window=128)
    state = eng.init_state()
    for t in range(T):                      # one micro-batch per tick
        x_t, y_t = traffic_at(t)            # (64, 16), (64,)
        tau_t = eng.taus(jax.random.PRNGKey(t))
        state, pvals = eng.observe(state, x_t, y_t, tau_t)  # (64,) smoothed
    # or: T ticks in ONE dispatch (xs: (T, 64, 16), ys/taus: (T, 64))
    state, pvals = eng.observe_many(state, xs, ys, taus)    # (T, 64)
    sets = eng.predict(state, x_query)      # (64, m, n_labels) full-CP query

Per-session p-values are bit-identical to running that session's stream
through ``core.online.run_stream`` alone (tested); sliding-window
eviction is the exact decremental update of ``serving.session``. The
read-only ``predict`` routes score-update + counting through the fused
Pallas kernel (``kernels/cp_update.py``) on TPU.

Tenants with no traffic on a tick are masked via ``active`` (state
bitwise unchanged, NaN p-value) — the micro-batch shape never changes.
When no ``window`` is set the engine auto-grows: once any session hits
capacity, every array doubles (host-side, O(log n) retraces total).

Two memory-system optimizations keep the hot tick O(cap) instead of
O(cap^2) (both bit-neutral, property-tested): the jitted step *donates*
its input state (``donate_argnums``), so the (S, cap, cap) distance
matrices update in place instead of being copied per tick — the input
``state`` is consumed by ``observe``/``observe_many`` and must not be
reused (pass ``donate=False`` to keep copy semantics) — and
``observe_many`` runs a whole chunk of ticks under one ``lax.scan``
dispatch, amortizing the per-dispatch overhead that otherwise dominates
at high tenant counts (``observe`` is its T=1 case).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import engine_utils
from repro.serving import session as sess_m
from repro.serving.session import Session


class ServingEngine:
    """Fixed-slot, fixed-shape multi-tenant CP serving engine.

    Parameters
    ----------
    n_sessions: number of tenant slots (the micro-batch width).
    capacity:   per-session padded training capacity.
    dim:        feature dimension.
    k:          k-NN neighbourhood size (paper's simplified k-NN measure).
    n_labels:   label alphabet for ``predict``.
    window:     sliding-window length (<= capacity); None => grow mode
                (capacity doubles when full instead of evicting).
    donate:     donate the input state to the jitted observe step (the
                O(cap) in-place path). The state passed to ``observe`` /
                ``observe_many`` is deleted by the call; reuse raises.
                ``False`` restores copy semantics (input stays valid).
    layout:     "ring" (default) — circular row indexing; a sliding tick
                evicts by advancing the per-session head pointer, so the
                (cap, cap) distance matrices are never shifted/copied.
                "compact" — the historic positional layout whose
                eviction compacts every leaf (O(cap^2) memory traffic
                per tick); kept as the benchmark baseline and the
                exactness oracle, bit-identical to "ring".
    instrument: attach telemetry (``repro.telemetry``): per-op latency
                histograms + trace records, and in-graph per-tick device
                counters (evictions / ring wraps / occupancy) folded
                into a lazy accumulator — drain with
                ``engine.telemetry.drain()``. Bit-identical to the
                uninstrumented engine (the stats only read the integer
                bookkeeping leaves; property-tested) and inside the
                <= 5 % overhead budget CI enforces on ``observe_many``.
    metrics:    ``MetricsRegistry`` to publish into (default: the
                process-wide registry). Only read when ``instrument``.
    tracer:     optional ``telemetry.Tracer`` — one JSONL record per
                engine dispatch. Only read when ``instrument``.
    sync_timing: with ``instrument``: block until the device finishes
                inside each timed op, so the latency histograms and
                trace records (``dispatch_s``) are device-true instead
                of enqueue time. Used by the replay harness; leave off
                on the serving hot path (it serializes dispatches).
    shards:     shard the tenant axis across this many devices
                (``core.distributed`` 1-D "tenants" mesh). A tick stays
                ONE dispatch — shard_map'd, zero collectives in the
                body — and every state leaf carries a tenant-sharded
                NamedSharding; results are bit-identical to the
                single-device vmap (property-tested). Requires
                ``n_sessions % shards == 0`` (pad uneven tenant counts
                with inactive lanes: ``distributed.pad_tenant_count``)
                and ``shards <= jax.device_count()``.
    """

    def __init__(self, *, n_sessions: int, capacity: int, dim: int, k: int,
                 n_labels: int = 2, window: int | None = None,
                 dtype=jnp.float32, donate: bool = True,
                 layout: str = "ring", instrument: bool = False,
                 metrics=None, tracer=None, sync_timing: bool = False,
                 shards: int = 1):
        if window is not None and window > capacity:
            raise ValueError(f"window {window} exceeds capacity {capacity}")
        if window is not None and window < 1:
            raise ValueError("window must be >= 1")
        if capacity < k:
            raise ValueError(f"capacity {capacity} < k {k}")
        if layout not in ("ring", "compact"):
            raise ValueError(f"unknown layout {layout!r}")
        if shards > 1 and n_sessions % shards != 0:
            raise ValueError(
                f"n_sessions {n_sessions} not divisible by shards "
                f"{shards}; pad with inactive lanes "
                "(core.distributed.pad_tenant_count)")
        self.n_sessions = n_sessions
        self.capacity = capacity
        self.dim = dim
        self.k = k
        self.n_labels = n_labels
        self.window = window
        self.dtype = dtype
        self.donate = donate
        self.layout = layout
        self.shards = shards
        self._mesh = None
        if shards > 1:
            from repro.core import distributed as dist
            self._mesh = dist.tenant_mesh(shards)
        # the fused sliding step: evict-if-full + observe + active mask
        # in one pass; grow mode (window=None) statically drops the
        # eviction machinery. A sliding window statically bounds
        # occupancy, so the tick runs on the [:window] block of every
        # leaf with ring modulus == window (cost scales with the window,
        # not the padded capacity) — observe_many verifies the
        # occupancy + ring-modulus invariants once per externally
        # supplied state.
        wmax = None if window is None else max(min(window, capacity), k)
        step_fn = (sess_m._sliding_step if layout == "ring"
                   else sess_m._sliding_step_compact)
        step = functools.partial(step_fn, k=k,
                                 evictable=window is not None, wmax=wmax)
        self._wmax = wmax
        self._w_checked = False
        self.telemetry = None
        if instrument:
            from repro.telemetry import EngineTelemetry
            self.telemetry = EngineTelemetry(
                engine="classification", metrics=metrics, tracer=tracer,
                sync=sync_timing,
                n_of=lambda s: s.knn.n, head_of=lambda s: s.head,
                wrap_of=lambda s: s.wrap)
        vstep = jax.vmap(step, in_axes=(0, 0, 0, 0, 0, 0))
        chunk = engine_utils.scan_chunk(
            vstep, self.telemetry.stats_fn if instrument else None)
        pred = jax.vmap(functools.partial(
            sess_m.predict_pvalues, k=k, n_labels=n_labels))
        if self._mesh is not None:
            from repro.core import distributed as dist
            chunk = dist.shard_tenant_chunk(chunk, self._mesh,
                                            with_stats=instrument)
            pred = dist.shard_tenant_fn(pred, self._mesh, (True, True))
        self._step_many = jax.jit(
            chunk, donate_argnums=(0,) if donate else ())
        self._predict = jax.jit(pred)
        # host-side upper bound on max_s n_s, for grow-mode occupancy
        # checks without a per-tick device sync
        self._n_bound: int | None = None

    # -- state --------------------------------------------------------------

    def init_state(self) -> Session:
        """Stacked Session pytree with a leading (n_sessions,) axis.

        Sliding engines confine every session's ring to the
        ``[:window]`` leaf block (``wrap == wmax``); grow mode uses the
        full capacity as the modulus (the ring never wraps there).
        With ``shards > 1`` every leaf is placed with a tenant-sharded
        NamedSharding across the mesh."""
        one = sess_m.init(self.capacity, self.dim, self.k,
                          dtype=self.dtype, wrap=self._wmax)
        state = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (self.n_sessions,) + a.shape),
            one)
        return self._shard_state(state)

    def _shard_state(self, state: Session) -> Session:
        if self._mesh is None:
            return state
        from repro.core import distributed as dist
        return dist.put_tenant_sharded(state, self._mesh)

    def taus(self, key) -> jnp.ndarray:
        """One tie-breaking uniform per session slot for this tick."""
        return jax.random.uniform(key, (self.n_sessions,), dtype=self.dtype)

    def _windows(self, state: Session) -> jnp.ndarray:
        cap = state.capacity
        w = cap + 1 if self.window is None else self.window  # +1: never evict
        return jnp.full((self.n_sessions,), w, dtype=jnp.int32)

    # -- serving ------------------------------------------------------------

    def observe(self, state: Session, x, y, tau, active=None):
        """One micro-batched tick: learn (x[s], y[s]) in every active slot.

        x: (S, dim); y: (S,); tau: (S,) tie-break uniforms; active: (S,)
        bool (default all). Returns (state, pvalues (S,)) — NaN p-value on
        inactive slots. In grow mode, auto-doubles capacity first if any
        session is full (host-side sync + retrace, O(log n) times total).
        The T=1 case of ``observe_many`` (bit-identical, tested); with
        ``donate=True`` (default) the input ``state`` is consumed.
        """
        if active is None:
            active = jnp.ones((self.n_sessions,), dtype=bool)
        state, p = self._dispatch(
            state, x[None], y[None], tau[None], active[None], op="observe")
        return state, p[0]

    def observe_many(self, state: Session, xs, ys, taus, active=None):
        """A chunk of T micro-batched ticks in ONE jitted dispatch.

        xs: (T, S, dim); ys: (T, S); taus: (T, S); active: (T, S) bool
        (default all). Returns (state, pvalues (T, S)) — tick t's row is
        bit-identical to calling ``observe`` T times (the chunk is a
        ``lax.scan`` over the same per-tick step; property-tested). In
        grow mode the whole chunk's worst-case occupancy is provisioned
        up front (capacity doubles until ``n + T <= cap``), so the scan
        never needs a mid-chunk host sync. With ``donate=True`` the
        input ``state`` is consumed.
        """
        if active is None:
            active = jnp.ones(xs.shape[:2], dtype=bool)
        return self._dispatch(state, xs, ys, taus, active,
                              op="observe_many")

    def _dispatch(self, state: Session, xs, ys, taus, active, *, op: str):
        """The shared observe/observe_many dispatch (telemetry-aware)."""
        state = engine_utils.ensure_room(self, state, xs.shape[0],
                                         lambda s: s.knn.n)
        engine_utils.check_window_occupancy(self, state, lambda s: s.knn.n,
                                            lambda s: s.wrap)
        args = (state, xs, ys.astype(jnp.int32), taus.astype(self.dtype),
                self._windows(state), active)
        if self.telemetry is None:
            return self._step_many(*args)
        T, S = xs.shape[:2]
        with self.telemetry.timed(op, signature=(xs.shape, self.capacity),
                                  ticks=T, tenants=S,
                                  capacity=self.capacity) as tm:
            state, (p, stats) = self._step_many(*args)
            tm.sync(p)
        self.telemetry.ticks.fold(stats)
        return state, p

    def lower_tick(self, ticks: int = 4):
        """Lower (but do NOT execute) a ``ticks``-long observe_many chunk.

        Returns the ``jax.stages.Lowered`` for the engine's compiled
        step on a zeros example batch — the artifact the static auditor
        (``repro.analysis.audit``) inspects for donation aliasing,
        collective-freedom and dense-materialization budgets. Tracing
        only: engine state and jit caches are untouched beyond the
        cache entry the first real tick would create anyway.
        """
        state = self.init_state()
        S, T = self.n_sessions, ticks
        xs = jnp.zeros((T, S, self.dim), self.dtype)
        ys = jnp.zeros((T, S), jnp.int32)
        taus = jnp.zeros((T, S), self.dtype)
        active = jnp.ones((T, S), dtype=bool)
        return self._step_many.lower(state, xs, ys, taus,
                                     self._windows(state), active)

    def reset_occupancy(self) -> None:
        """Forget the host-side occupancy bound (grow mode) and the
        window-invariant check; the next ``observe`` re-syncs/re-checks
        from device. Call after substituting a state that this engine
        did not produce."""
        self._n_bound = None
        self._w_checked = False

    def grow(self, state: Session, factor: int = 2) -> Session:
        """Double every session's capacity (host-side, preserves state).

        ``self.capacity`` follows the grown state so ``meta()`` and
        ``init_state()`` stay consistent with the states this engine
        produces. Session-level grow normalizes each ring to linear
        order with a full-capacity modulus; a sliding engine pins the
        modulus back to its window block (the normalized state fits it:
        head == 0, n <= window)."""
        grow_one = functools.partial(sess_m.grow, factor=factor)
        if self.telemetry is not None:
            with self.telemetry.timed("grow", tenants=self.n_sessions,
                                      capacity=self.capacity * factor,
                                      signature=self.capacity):
                out = jax.vmap(grow_one)(state)
        else:
            out = jax.vmap(grow_one)(state)
        self.capacity = out.capacity
        if self._wmax is not None:
            out = Session(out.knn, out.D, out.head, out.aid,
                          jnp.full_like(out.wrap, self._wmax))
        return self._shard_state(out)

    def predict(self, state: Session, X_test) -> jnp.ndarray:
        """Read-only full-CP p-values per session: (S, m, n_labels).

        X_test: (S, m, dim) per-session query batch, or (m, dim) broadcast
        to every session. One vmapped jitted dispatch for all sessions;
        inside it the fused kernel (Pallas on TPU) does the score update
        + count in a single pass.
        """
        if X_test.ndim == 2:
            X_test = jnp.broadcast_to(
                X_test, (self.n_sessions,) + X_test.shape)
        if self.telemetry is None:
            return self._predict(state, X_test)
        with self.telemetry.timed("predict",
                                  signature=(X_test.shape, self.capacity),
                                  tenants=self.n_sessions,
                                  capacity=self.capacity) as tm:
            return tm.sync(self._predict(state, X_test))

    # -- snapshot -----------------------------------------------------------

    def meta(self) -> dict[str, Any]:
        """JSON-serializable engine config, stored alongside snapshots."""
        return {
            "n_sessions": self.n_sessions,
            "capacity": self.capacity,
            "dim": self.dim,
            "k": self.k,
            "n_labels": self.n_labels,
            "window": self.window,
            "dtype": jnp.dtype(self.dtype).name,
            "shards": self.shards,
        }

    @classmethod
    def from_meta(cls, meta: dict[str, Any]) -> "ServingEngine":
        meta = dict(meta)
        meta["dtype"] = jnp.dtype(meta.get("dtype", "float32"))
        # a snapshot from a sharded fleet restores wherever it lands:
        # fall back to a single device when the saved shard count is
        # not available here (results are bit-identical either way)
        shards = int(meta.pop("shards", 1))
        if (shards > 1 and shards <= jax.device_count()
                and meta["n_sessions"] % shards == 0):
            meta["shards"] = shards
        return cls(**meta)


__all__ = ["ServingEngine"]
