"""Tenant-state snapshot/restore for the serving engine.

Wires ``serving.engine`` state through ``checkpoint.store.CheckpointStore``
so tenant CP state survives process restarts: atomic commit (a crash
mid-write can never corrupt the latest snapshot), per-shard checksums,
async double-buffered writes. The engine config travels in the
manifest's ``extra`` field, so ``restore_engine`` can rebuild the whole
serving stack from a bare directory::

    store = SessionStore("/var/lib/cp-serving")
    store.save(step, state, meta=engine.meta())     # during serving
    ...
    engine, state, step = SessionStore(root).restore_engine()  # on restart

Restore is self-describing: the target pytree is reconstructed from the
manifest's leaf shapes (capacity growth between snapshots is fine — the
restored engine adopts the snapshot's capacity, not the configured one).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.core.online import OnlineKnnState
from repro.regression.engine import RegressionServingEngine
from repro.regression.stream import RegStreamState
from repro.serving.engine import ServingEngine
from repro.serving.session import Session


def _like_from_manifest(manifest: dict):
    """Zero-filled restore target (possibly batched) matching the leaves.

    8 leaves = classification ``Session`` (X, y, best, n, D, head, aid,
    wrap); 10 leaves = regression ``RegStreamState`` (X, y, D, nbr_d,
    nbr_y, n, head, aid, wrap, nbr_a). Pre-ring snapshots carried 5 / 6
    leaves (no ring bookkeeping); they restore into a plain leaf list
    that ``_from_legacy`` upgrades to a linear-layout ring state.
    """
    specs = manifest["leaves"]
    zeros = [jnp.zeros(tuple(s["shape"]), dtype=s["dtype"]) for s in specs]
    if len(specs) in (5, 6):
        return zeros  # legacy linear snapshot: synthesized below
    if len(specs) == 8:
        X, y, best, n, D, head, aid, wrap = zeros
        return Session(OnlineKnnState(X, y, best, n), D, head, aid, wrap)
    if len(specs) == 10:
        return RegStreamState(*zeros)
    raise ValueError(
        f"snapshot has {len(specs)} leaves; expected 8 (classification "
        "Session), 10 (regression RegStreamState), or the legacy 5/6 "
        "linear forms — not a serving snapshot?")


def _from_legacy(leaves):
    """Upgrade a pre-ring linear snapshot to the ring layout.

    The legacy layout was arrival-ordered rows [0, n): exactly a ring at
    head == 0 with a full-capacity modulus and positional arrival ids.
    The regression neighbour-arrival-id lists (which the legacy format
    never stored) are reconstructed exactly from the saved distance
    matrix: per row, a ties-toward-lowest-index top-k — fit's tie rule,
    which positional storage realized by construction.
    """
    if len(leaves) == 5:
        X, y, best, n, D = leaves
    else:
        X, y, D, nbr_d, nbr_y, n = leaves
    cap = D.shape[-1]
    head = jnp.zeros_like(n)
    pos = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), y.shape)
    live = pos < jnp.asarray(n)[..., None]
    aid = jnp.where(live, pos, 0).astype(jnp.int32)
    wrap = jnp.full_like(n, cap)
    if len(leaves) == 5:
        return Session(OnlineKnnState(X, y, best, n), D, head, aid, wrap)

    k = nbr_d.shape[-1]

    def rebuild_nbr_a(Di):
        neg, idxm = jax.lax.top_k(-Di, k)
        return jnp.where(-neg >= 1e30, 0, idxm).astype(jnp.int32)

    fn = rebuild_nbr_a
    for _ in range(D.ndim - 2):
        fn = jax.vmap(fn)
    nbr_a = fn(D)
    return RegStreamState(X, y, D, nbr_d, nbr_y, n, head, aid, wrap,
                          nbr_a)


def _fit_ring_modulus(engine, state):
    """Align a restored state's ring modulus with the target engine.

    A pre-ring (legacy) snapshot restores with a full-capacity modulus;
    a sliding engine runs its ring inside the ``[:window]`` block. The
    two agree whenever the state is unwrapped (head == 0) and fits the
    window — then the modulus can simply be re-pinned. Anything else is
    a real mismatch and is left for ``check_window_occupancy`` to
    reject with its diagnostic.
    """
    if engine._wmax is None:
        return state
    wrap = jnp.asarray(state.wrap)
    if (int(jnp.max(wrap)) == engine._wmax
            and int(jnp.min(wrap)) == engine._wmax):
        return state
    n = state.n if isinstance(state, RegStreamState) else state.knn.n
    if int(jnp.max(state.head)) != 0 or int(jnp.max(n)) > engine._wmax:
        return state  # genuinely incompatible; the engine check reports
    new_wrap = jnp.full_like(wrap, engine._wmax)
    if isinstance(state, RegStreamState):
        return RegStreamState(state.X, state.y, state.D, state.nbr_d,
                              state.nbr_y, state.n, state.head, state.aid,
                              new_wrap, state.nbr_a)
    return Session(state.knn, state.D, state.head, state.aid, new_wrap)


class SessionStore:
    """Crash-safe snapshot store for (batched) serving sessions.

    ``metrics`` / ``tracer`` (optional, ``repro.telemetry``) time every
    save and restore: histograms ``snapshot_save_s`` /
    ``snapshot_restore_s`` and one trace record per call. A
    non-blocking ``save`` measures the host-copy + enqueue time (the
    cost the serving loop actually pays); ``blocking=True`` measures
    through the committed write.
    """

    def __init__(self, root: str, keep: int = 3, *, metrics=None,
                 tracer=None, injector=None):
        self.root = root
        self._store = CheckpointStore(root, keep=keep, injector=injector)
        self._metrics = metrics
        self._tracer = tracer

    def _timed(self, op: str, fn, *, tenants=None):
        import time as _time

        t0 = _time.perf_counter()
        out = fn()
        wall = _time.perf_counter() - t0
        if self._metrics is not None:
            self._metrics.histogram(f"{op}_s").observe(wall)
        if self._tracer is not None:
            self._tracer.record(op, wall, tenants=tenants)
        return out

    def save(self, step: int, state: Session, *, meta: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot ``state``; ``meta`` (e.g. ``engine.meta()``) rides in
        the manifest. Async by default — call ``wait()`` before exit."""
        self._timed(
            "snapshot_save",
            lambda: self._store.save(step, state, blocking=blocking,
                                     extra=meta or {}))

    def wait(self) -> None:
        self._store.wait()

    def latest_step(self) -> int | None:
        return self._store.latest_step()

    def discard(self, step: int) -> None:
        """Drop a step so ``latest_step`` never points at it (see
        ``CheckpointStore.discard``)."""
        self._store.discard(step)

    def restore(self, step: int | None = None
                ) -> tuple[Session, int, dict[str, Any]]:
        """Load (state, step, meta) — target shapes come from the manifest.

        Without an explicit ``step``, a corrupted latest snapshot falls
        back to the previous committed one (``restore_fallback_total``
        counts each skipped step); an explicit ``step`` still raises on
        corruption.
        """
        def _on_fallback(s, exc):
            if self._metrics is not None:
                self._metrics.counter("restore_fallback_total").inc()

        def _restore():
            state, s = self._store.restore(
                _like_from_manifest, step, on_fallback=_on_fallback)
            if isinstance(state, list):  # legacy 5/6-leaf linear snapshot
                state = _from_legacy(state)
            manifest = self._store.read_manifest(s)
            return state, s, manifest.get("extra", {})

        return self._timed("snapshot_restore", _restore)

    def restore_engine(self, step: int | None = None):
        """Rebuild the engine *and* its state from the latest snapshot.

        Returns ``(engine, state, step)`` — a ``ServingEngine`` for
        classification snapshots, a ``RegressionServingEngine`` when the
        saved meta says ``mode == "regression"``. Geometry (n_sessions,
        capacity, dim) is taken from the saved arrays; k / n_labels /
        window / dtype from the saved meta.
        """
        state, step, meta = self.restore(step)
        if "k" not in meta:
            raise ValueError(
                f"snapshot step {step} carries no engine meta (saved "
                "without meta=engine.meta()?) — use restore() and "
                "construct the ServingEngine yourself")
        regression = isinstance(state, RegStreamState)
        if regression != (meta.get("mode") == "regression"):
            raise ValueError(
                f"snapshot step {step}: state/meta mode mismatch "
                f"({type(state).__name__} vs meta mode "
                f"{meta.get('mode')!r})")
        X = state.X if regression else state.knn.X
        meta = {
            **meta,
            "n_sessions": int(state.D.shape[0]),
            "capacity": int(state.D.shape[-1]),
            "dim": int(X.shape[-1]),
        }
        if regression:
            engine = RegressionServingEngine.from_meta(meta)
        else:
            engine = ServingEngine.from_meta(meta)
        state = _fit_ring_modulus(engine, state)
        # a sharded engine serves sharded state: lay the restored leaves
        # out across the tenant mesh (no-op for shards == 1)
        state = engine._shard_state(state)
        return engine, state, step


class AsyncShardedSaver:
    """Double-buffered sharded snapshot pipeline over a ``SessionStore``.

    Overlaps host I/O with device compute. ``save(step, state)`` slices
    the stacked state into per-shard tenant blocks *on device* — the
    slices are fresh buffers, so the serving loop is free to donate and
    overwrite ``state`` on the very next tick — then hands them to a
    background worker that pulls each shard to host in sequence
    (``device_get`` of shard *i* overlaps the tick that is already
    computing, and with one block per device the per-shard pulls drain
    different devices back-to-back), reassembles the full host state,
    and commits it through the store's atomic write path. A bounded
    queue (default depth 2: one snapshot being written + one buffered)
    gives double buffering with backpressure instead of unbounded
    device-memory growth when snapshots outpace disk.

    Transient write errors (``OSError``, incl. the chaos harness's
    ``TransientWriteError``) are retried up to ``retries`` times on a
    keyed deterministic exponential-backoff schedule
    (``faults.backoff_schedule(seed, step, ...)`` — same (seed, step),
    same waits; ``snapshot_retries_total`` counts them). Anything else
    (incl. ``PermanentWriteError``) surfaces immediately. When retries
    are exhausted the failed step is DISCARDED from the store before
    the error is parked (``snapshot_failed_steps_total``), so
    ``latest_step()`` can never point at a half-written snapshot.

    Worker errors surface on the *next* ``save``/``wait`` call — the
    serving loop finds out, just not mid-tick. Always ``wait()`` (or
    ``close()``) before reading the store back.
    """

    def __init__(self, store: SessionStore, shards: int, *, depth: int = 2,
                 metrics=None, retries: int = 3, retry_base_s: float = 0.05,
                 seed: int = 0):
        import queue as _queue
        import threading as _threading

        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.store = store
        self.shards = shards
        self.retries = int(retries)
        self.retry_base_s = float(retry_base_s)
        self._seed = int(seed)
        self._metrics = metrics
        self._q: Any = _queue.Queue(maxsize=depth)
        self._err: BaseException | None = None
        self._worker = _threading.Thread(
            target=self._run, name="sharded-snapshot-saver", daemon=True)
        self._worker.start()

    def _check_err(self) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async snapshot save failed") from err

    def save(self, step: int, state, *, meta: dict | None = None) -> None:
        """Enqueue a snapshot of ``state`` (blocks only when the queue
        is full — backpressure at ``depth`` in-flight snapshots)."""
        self._check_err()
        S = jax.tree_util.tree_leaves(state)[0].shape[0]
        cuts = [S * i // self.shards for i in range(self.shards + 1)]
        # device-side slicing: new buffers per shard, donation-safe
        slices = [
            jax.tree_util.tree_map(lambda l: l[cuts[i]:cuts[i + 1]], state)
            for i in range(self.shards)]
        self._q.put((step, slices, meta))

    def _commit_with_retry(self, step: int, full, meta) -> None:
        import time as _time

        from repro.robustness.faults import (PermanentWriteError,
                                             backoff_schedule)

        delays = backoff_schedule(self._seed, step, self.retries,
                                  self.retry_base_s)
        attempt = 0
        while True:
            try:
                self.store.save(step, full, meta=meta, blocking=True)
                return
            except PermanentWriteError:
                raise
            except OSError:
                if attempt >= self.retries:
                    raise
                if self._metrics is not None:
                    self._metrics.counter("snapshot_retries_total").inc()
                _time.sleep(delays[attempt])
                attempt += 1

    def _run(self) -> None:
        import time as _time

        import numpy as np

        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, slices, meta = item
            try:
                t0 = _time.perf_counter()
                host = [jax.device_get(s) for s in slices]  # shard-by-shard
                full = jax.tree_util.tree_map(
                    lambda *ls: np.concatenate(ls, axis=0), *host)
                self._commit_with_retry(step, full, meta)
                if self._metrics is not None:
                    self._metrics.histogram(
                        "snapshot_async_save_s", shards=self.shards
                    ).observe(_time.perf_counter() - t0)
            except BaseException as e:  # surfaced on next save()/wait()
                # failed for good: drop the step so latest_step() can
                # never point at a half-written snapshot (discard never
                # raises — the original error is what surfaces)
                self.store.discard(step)
                if self._metrics is not None:
                    self._metrics.counter(
                        "snapshot_failed_steps_total").inc()
                self._err = e
            finally:
                self._q.task_done()

    def wait(self) -> None:
        """Block until every enqueued snapshot is committed."""
        self._q.join()
        self.store.wait()
        self._check_err()

    def close(self) -> None:
        """Drain, stop the worker, and surface any pending error."""
        self._q.put(None)
        self._q.join()
        self._worker.join()
        self.store.wait()
        self._check_err()


__all__ = ["SessionStore", "AsyncShardedSaver"]
