"""Tenant-state snapshot/restore for the serving engine.

Wires ``serving.engine`` state through ``checkpoint.store.CheckpointStore``
so tenant CP state survives process restarts: atomic commit (a crash
mid-write can never corrupt the latest snapshot), per-shard checksums,
async double-buffered writes. The engine config travels in the
manifest's ``extra`` field, so ``restore_engine`` can rebuild the whole
serving stack from a bare directory::

    store = SessionStore("/var/lib/cp-serving")
    store.save(step, state, meta=engine.meta())     # during serving
    ...
    engine, state, step = SessionStore(root).restore_engine()  # on restart

Restore is self-describing: the target pytree is reconstructed from the
manifest's leaf shapes (capacity growth between snapshots is fine — the
restored engine adopts the snapshot's capacity, not the configured one).
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.core.online import OnlineKnnState
from repro.regression.engine import RegressionServingEngine
from repro.regression.stream import RegStreamState
from repro.serving.engine import ServingEngine
from repro.serving.session import Session


def _like_from_manifest(manifest: dict):
    """Zero-filled session pytree (possibly batched) matching the leaves.

    5 leaves = classification ``Session`` (X, y, best, n, D); 6 leaves =
    regression ``RegStreamState`` (X, y, D, nbr_d, nbr_y, n).
    """
    specs = manifest["leaves"]
    if len(specs) == 5:
        X, y, best, n, D = (
            jnp.zeros(tuple(s["shape"]), dtype=s["dtype"]) for s in specs)
        return Session(OnlineKnnState(X, y, best, n), D)
    if len(specs) == 6:
        X, y, D, nbr_d, nbr_y, n = (
            jnp.zeros(tuple(s["shape"]), dtype=s["dtype"]) for s in specs)
        return RegStreamState(X, y, D, nbr_d, nbr_y, n)
    raise ValueError(
        f"snapshot has {len(specs)} leaves; expected 5 (classification "
        "Session) or 6 (regression RegStreamState) — not a serving "
        "snapshot?")


class SessionStore:
    """Crash-safe snapshot store for (batched) serving sessions."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self._store = CheckpointStore(root, keep=keep)

    def save(self, step: int, state: Session, *, meta: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot ``state``; ``meta`` (e.g. ``engine.meta()``) rides in
        the manifest. Async by default — call ``wait()`` before exit."""
        self._store.save(step, state, blocking=blocking, extra=meta or {})

    def wait(self) -> None:
        self._store.wait()

    def latest_step(self) -> int | None:
        return self._store.latest_step()

    def restore(self, step: int | None = None
                ) -> tuple[Session, int, dict[str, Any]]:
        """Load (state, step, meta) — target shapes come from the manifest."""
        step = step if step is not None else self._store.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed snapshots in {self.root}")
        manifest = self._store.read_manifest(step)
        like = _like_from_manifest(manifest)
        state, step = self._store.restore(like, step)
        return state, step, manifest.get("extra", {})

    def restore_engine(self, step: int | None = None):
        """Rebuild the engine *and* its state from the latest snapshot.

        Returns ``(engine, state, step)`` — a ``ServingEngine`` for
        classification snapshots, a ``RegressionServingEngine`` when the
        saved meta says ``mode == "regression"``. Geometry (n_sessions,
        capacity, dim) is taken from the saved arrays; k / n_labels /
        window / dtype from the saved meta.
        """
        state, step, meta = self.restore(step)
        if "k" not in meta:
            raise ValueError(
                f"snapshot step {step} carries no engine meta (saved "
                "without meta=engine.meta()?) — use restore() and "
                "construct the ServingEngine yourself")
        regression = isinstance(state, RegStreamState)
        if regression != (meta.get("mode") == "regression"):
            raise ValueError(
                f"snapshot step {step}: state/meta mode mismatch "
                f"({type(state).__name__} vs meta mode "
                f"{meta.get('mode')!r})")
        X = state.X if regression else state.knn.X
        meta = {
            **meta,
            "n_sessions": int(state.D.shape[0]),
            "capacity": int(state.D.shape[-1]),
            "dim": int(X.shape[-1]),
        }
        if regression:
            return RegressionServingEngine.from_meta(meta), state, step
        return ServingEngine.from_meta(meta), state, step


__all__ = ["SessionStore"]
